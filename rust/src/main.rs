//! `aigc-edge` — leader entrypoint.
//!
//! See `cli::USAGE` for subcommands. The binary is self-contained once
//! `make artifacts` has produced the AOT executables: Python never runs
//! on any path below.

use anyhow::{bail, Context, Result};

use aigc_edge::bandwidth::{
    Allocator, AllocatorPool, EqualAllocator, ProportionalAllocator, PsoAllocator, PsoConfig,
};
use aigc_edge::bench;
use aigc_edge::cli::{Args, USAGE};
use aigc_edge::config::{ArrivalProcessKind, ExperimentConfig};
use aigc_edge::coordinator::{profile_batch_delay, ProfileConfig, SolveMode};
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::faults::{FaultModeKind, FaultScript, MigrationPolicyKind};
use aigc_edge::metrics::{MetricsMode, OutcomeAccumulator, OutcomeStats};
use aigc_edge::obs;
use aigc_edge::quality::{PowerLawQuality, QualityModel, TableQuality};
use aigc_edge::routing::RouterKind;
use aigc_edge::runtime::ArtifactStore;
use aigc_edge::scheduler::{
    BatchScheduler, FixedSizeBatching, GreedyBatching, SingleInstance, Stacking, StackingConfig,
};
use aigc_edge::sim::{
    simulate_cluster_pooled_traced, simulate_dynamic_streaming, simulate_dynamic_traced,
    simulate_event_cluster_pooled_traced, ClusterConfig, Disposition, DynamicConfig,
    EventClusterConfig,
};
use aigc_edge::trace::{ArrivalStream, ArrivalTrace};

/// Build the STACKING scheduler from config (0 = derive T* bound).
fn stacking_from(cfg: &ExperimentConfig) -> Stacking {
    Stacking::new(StackingConfig {
        t_star_max: (cfg.stacking.t_star_max > 0).then_some(cfg.stacking.t_star_max),
        max_steps: cfg.stacking.max_steps,
        ..Default::default()
    })
}
use aigc_edge::server::{serve, ServerConfig};
use aigc_edge::sim::solve_joint;
use aigc_edge::trace::generate;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    match args.command.as_str() {
        "serve" => cmd_serve(&args),
        "simulate" => cmd_simulate(&args),
        "dynamic" => cmd_dynamic(&args),
        "cluster" => cmd_cluster(&args),
        "faults" => cmd_faults(&args),
        "trace" => cmd_trace(&args),
        "profile" => cmd_profile(&args),
        "figures" => cmd_figures(&args),
        "perf" => cmd_perf(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

/// Scheduler selection shared by `simulate` and `dynamic`.
fn scheduler_from(args: &Args, cfg: &ExperimentConfig) -> Result<Box<dyn BatchScheduler>> {
    Ok(match args.get_or("scheduler", "stacking").as_str() {
        "stacking" => Box::new(stacking_from(cfg)),
        "single" => Box::new(SingleInstance::default()),
        "greedy" => Box::new(GreedyBatching),
        "fixed" => Box::new(FixedSizeBatching::default()),
        other => bail!("unknown scheduler '{other}'"),
    })
}

/// The solve/sweep fan-out knob: `--threads` overrides `[perf]
/// threads` from the config. Validation matches the config error:
/// the message lists the valid values.
fn threads_from(args: &Args, cfg: &ExperimentConfig) -> Result<usize> {
    match args.get("threads") {
        None => Ok(cfg.perf.threads),
        Some(v) => v.parse::<usize>().map_err(|_| {
            anyhow::anyhow!(
                "--threads must be 0 (auto-detect) or a positive thread count, got '{v}'"
            )
        }),
    }
}

/// Allocator selection shared by `simulate` and `dynamic`. These
/// single-server commands spend the thread budget inside the solve:
/// PSO fans its particle fitness out across `threads`.
fn allocator_from(args: &Args, threads: usize) -> Result<Box<dyn Allocator>> {
    Ok(match args.get_or("allocator", "pso").as_str() {
        "pso" => Box::new(PsoAllocator::new(PsoConfig { threads, ..Default::default() })),
        "equal" => Box::new(EqualAllocator),
        "proportional" => Box::new(ProportionalAllocator),
        other => bail!("unknown allocator '{other}' (valid: pso, equal, proportional)"),
    })
}

/// Allocator-pool selection for the cluster engines: PSO gets one
/// instance per server (warm-start state stays on its server —
/// `--warm-start true` enables the carry); the stateless baselines
/// share one instance, which is equivalent. Cluster commands spend the
/// thread budget at the *engine* level (per-server solve fan-out), so
/// each PSO instance stays serial — nesting both would oversubscribe.
fn allocator_pool_from(args: &Args, servers: usize) -> Result<AllocatorPool> {
    let warm_start = match args.get("warm-start") {
        None | Some("false") => false,
        Some("true") => true,
        Some(other) => bail!("--warm-start must be true or false, got '{other}'"),
    };
    let name = args.get_or("allocator", "pso");
    if warm_start && name != "pso" {
        bail!("--warm-start only applies to --allocator pso (got '{name}')");
    }
    Ok(match name.as_str() {
        "pso" => AllocatorPool::per_server(servers, |_| {
            Box::new(PsoAllocator::new(PsoConfig { warm_start, ..Default::default() }))
        }),
        "equal" => AllocatorPool::shared(Box::new(EqualAllocator)),
        "proportional" => AllocatorPool::shared(Box::new(ProportionalAllocator)),
        other => bail!("unknown allocator '{other}' (valid: pso, equal, proportional)"),
    })
}

fn load_config(args: &Args) -> Result<ExperimentConfig> {
    match args.get("config") {
        Some(path) => ExperimentConfig::from_file(std::path::Path::new(path)),
        None => Ok(ExperimentConfig::paper()),
    }
}

fn quality_model(cfg: &ExperimentConfig) -> Result<Box<dyn QualityModel>> {
    use aigc_edge::config::QualityModelKind::*;
    Ok(match cfg.quality {
        PaperPowerLaw => Box::new(PowerLawQuality::paper()),
        CalibratedPowerLaw => {
            Box::new(PowerLawQuality::from_quality_json(&cfg.quality_json_path())?)
        }
        CalibratedTable => Box::new(TableQuality::from_quality_json(&cfg.quality_json_path())?),
    })
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.expect_only(&["addr", "config", "epoch-ms", "max-batch"])?;
    let cfg = load_config(args)?;
    let addr = args.get_or("addr", "127.0.0.1:7878");
    let server_cfg = ServerConfig {
        epoch_ms: args.get_u64("epoch-ms", 200)?,
        max_batch: args.get_usize("max-batch", 32)?,
    };
    let artifacts_dir = cfg.artifacts_dir.clone();
    let server = serve(artifacts_dir, cfg, server_cfg, &addr)?;
    println!("listening on {} — protocol: GEN <deadline_s> <eta> | STATS | QUIT", server.addr);
    // Run until killed.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_simulate(args: &Args) -> Result<()> {
    args.expect_only(&["config", "scheduler", "allocator", "seed", "threads"])?;
    let mut cfg = load_config(args)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    let scheduler = scheduler_from(args, &cfg)?;
    let allocator = allocator_from(args, threads_from(args, &cfg)?)?;
    let quality = quality_model(&cfg)?;
    let delay = BatchDelayModel::new(cfg.delay.a, cfg.delay.b);
    let workload = generate(&cfg.scenario, cfg.seed);
    let sol =
        solve_joint(&workload, scheduler.as_ref(), allocator.as_ref(), &delay, quality.as_ref());

    println!(
        "scenario: K={} deadlines U[{}, {}]s B={} Hz",
        cfg.scenario.num_services,
        cfg.scenario.deadline_lo,
        cfg.scenario.deadline_hi,
        cfg.scenario.total_bandwidth_hz
    );
    println!("scheduler={} allocator={}", scheduler.name(), allocator.name());
    println!(
        "mean FID {:.3} | outages {} | mean steps {:.1} | makespan {:.2}s | inner evals {}",
        sol.outcome.mean_quality(),
        sol.outcome.outages(),
        sol.outcome.mean_steps(),
        sol.outcome.schedule.makespan(),
        sol.inner_evals
    );
    for s in &sol.outcome.services {
        println!(
            "  svc {:>2}: deadline {:>5.2}s steps {:>3} gen {:>5.2}s tx {:>4.2}s e2e {:>5.2}s {}",
            s.id,
            s.deadline,
            s.steps,
            s.gen_delay,
            s.tx_delay,
            s.e2e_delay,
            if s.met { "ok" } else { "OUTAGE" }
        );
    }
    Ok(())
}

/// Apply the arrival/epoching flags `dynamic` and `cluster` share.
fn apply_dynamic_flags(args: &Args, cfg: &mut ExperimentConfig) -> Result<()> {
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    match args.get("process") {
        None => {}
        Some("poisson") => cfg.arrival.process = ArrivalProcessKind::Poisson,
        Some("burst") => cfg.arrival.process = ArrivalProcessKind::Burst,
        Some(other) => bail!("unknown arrival process '{other}'"),
    }
    cfg.arrival.rate_hz = args.get_f64("rate", cfg.arrival.rate_hz)?;
    cfg.arrival.horizon_s = args.get_f64("horizon", cfg.arrival.horizon_s)?;
    cfg.dynamic.epoch_s = args.get_f64("epoch-s", cfg.dynamic.epoch_s)?;
    cfg.dynamic.max_batch = args.get_usize("max-batch", cfg.dynamic.max_batch)?;
    cfg.dynamic.window_s = args.get_f64("window", cfg.dynamic.window_s)?;
    cfg.dynamic.plan_horizon_s = args.get_f64("plan-horizon", cfg.dynamic.plan_horizon_s)?;
    cfg.dynamic.solve_latency_s = args.get_f64("solve-latency", cfg.dynamic.solve_latency_s)?;
    if let Some(name) = args.get("solve-mode") {
        cfg.dynamic.solve_mode = SolveMode::from_name(name)?;
    }
    match args.get("adaptive-horizon") {
        None => {}
        Some("true") => cfg.dynamic.plan_horizon_adaptive = true,
        Some("false") => cfg.dynamic.plan_horizon_adaptive = false,
        Some(other) => bail!("--adaptive-horizon must be true or false, got '{other}'"),
    }
    match args.get("no-admission") {
        None => {}
        Some("true") => cfg.dynamic.admission = false,
        Some("false") => cfg.dynamic.admission = true,
        Some(other) => bail!("--no-admission must be true or false, got '{other}'"),
    }
    Ok(())
}

/// Apply the fleet flags `cluster` and `faults` share.
fn apply_cluster_flags(args: &Args, cfg: &mut ExperimentConfig) -> Result<()> {
    cfg.cluster.servers = args.get_usize("servers", cfg.cluster.servers)?;
    if let Some(name) = args.get("router") {
        cfg.cluster.router = RouterKind::from_name(name)?;
    }
    cfg.cluster.speed_min = args.get_f64("speed-min", cfg.cluster.speed_min)?;
    cfg.cluster.speed_max = args.get_f64("speed-max", cfg.cluster.speed_max)?;
    Ok(())
}

fn cmd_dynamic(args: &Args) -> Result<()> {
    args.expect_only(&[
        "config",
        "process",
        "rate",
        "horizon",
        "epoch-s",
        "max-batch",
        "window",
        "plan-horizon",
        "adaptive-horizon",
        "solve-latency",
        "solve-mode",
        "no-admission",
        "metrics-mode",
        "trace-out",
        "trace-spans",
        "scheduler",
        "allocator",
        "seed",
        "threads",
    ])?;
    let mut cfg = load_config(args)?;
    apply_dynamic_flags(args, &mut cfg)?;
    if let Some(name) = args.get("metrics-mode") {
        cfg.metrics.mode = match MetricsMode::from_name(name) {
            Some(mode) => mode,
            None => bail!("--metrics-mode must be exact or streaming, got '{name}'"),
        };
    }
    cfg.validate()?;

    let scheduler = scheduler_from(args, &cfg)?;
    let allocator = allocator_from(args, threads_from(args, &cfg)?)?;
    let quality = quality_model(&cfg)?;
    let delay = BatchDelayModel::new(cfg.delay.a, cfg.delay.b);
    let mut dyn_cfg = DynamicConfig::from(&cfg.dynamic);
    dyn_cfg.cache = cfg.cache;
    if cfg.metrics.mode == MetricsMode::Streaming {
        return run_dynamic_streaming(
            args,
            &cfg,
            scheduler.as_ref(),
            allocator.as_ref(),
            &delay,
            quality.as_ref(),
            &dyn_cfg,
        );
    }
    let trace = ArrivalTrace::generate(&cfg.scenario, &cfg.arrival, cfg.seed);
    if let Some(path) = args.get("trace-out") {
        std::fs::write(path, trace.to_csv()).with_context(|| format!("writing trace {path}"))?;
        println!("replayable arrival trace written to {path}");
    }
    println!(
        "dynamic scenario: {:?} rate {} Hz over {}s | epoch {}s max-batch {} | plan horizon {}s | \
         solve {} @ {}s | admission {}",
        cfg.arrival.process,
        cfg.arrival.rate_hz,
        cfg.arrival.horizon_s,
        cfg.dynamic.epoch_s,
        cfg.dynamic.max_batch,
        cfg.dynamic.plan_horizon_s,
        cfg.dynamic.solve_mode.name(),
        cfg.dynamic.solve_latency_s,
        cfg.dynamic.admission,
    );
    println!(
        "{} arrivals (empirical rate {:.2} Hz); scheduler={} allocator={}",
        trace.len(),
        trace.mean_rate_hz(),
        scheduler.name(),
        allocator.name()
    );
    // Flight recorder: a live Recorder when `--trace-spans` asks for a
    // capture, the zero-cost NullSink otherwise — same engine path,
    // bit-identical outputs either way.
    let span_path = args.get("trace-spans");
    let mut rec = obs::Recorder::new();
    let mut null = obs::NullSink;
    let tracer: &mut dyn obs::TraceSink = if span_path.is_some() { &mut rec } else { &mut null };
    let report = simulate_dynamic_traced(
        &trace,
        scheduler.as_ref(),
        allocator.as_ref(),
        &delay,
        quality.as_ref(),
        &dyn_cfg,
        tracer,
    );

    // Windowed view: one row every ~window/3 of simulated time.
    let mut table = aigc_edge::bench::TableWriter::new(
        "sliding-window serving metrics (sampled at epoch solves)",
        &["t s", "queue", "arr/s", "mean FID", "outage", "p50 e2e", "p95 e2e", "p99 e2e"],
    );
    let mut next_sample = 0.0;
    for e in &report.epochs {
        if e.t_solve_s < next_sample {
            continue;
        }
        next_sample = e.t_solve_s + cfg.dynamic.window_s / 3.0;
        table.row(&[
            format!("{:.1}", e.t_solve_s),
            e.queue_depth.to_string(),
            format!("{:.2}", e.arrival_rate_hz),
            format!("{:.1}", e.mean_quality_w),
            format!("{:.3}", e.outage_rate_w),
            format!("{:.2}", e.p50_e2e_w),
            format!("{:.2}", e.p95_e2e_w),
            format!("{:.2}", e.p99_e2e_w),
        ]);
    }
    table.finish();
    println!(
        "served {}/{} ({} rejected on arrival, {} expired in queue) over {} epochs, {:.1}s simulated",
        report.served(),
        report.outcomes.len(),
        report
            .outcomes
            .iter()
            .filter(|o| o.disposition == Disposition::RejectedOnArrival)
            .count(),
        report
            .outcomes
            .iter()
            .filter(|o| o.disposition == Disposition::ExpiredInQueue)
            .count(),
        report.epochs.len(),
        report.horizon_s,
    );
    println!(
        "mean FID {:.2} | outage rate {:.3} | e2e p50 {:.2}s p95 {:.2}s p99 {:.2}s | mean wait {:.2}s | throughput {:.2}/s | peak queue {}",
        report.mean_quality(),
        report.outage_rate(),
        report.e2e_percentile(50.0),
        report.e2e_percentile(95.0),
        report.e2e_percentile(99.0),
        report.mean_wait_s(),
        report.throughput_hz(),
        report.peak_queue_depth(),
    );
    if cfg.dynamic.solve_latency_s > 0.0 && !report.epochs.is_empty() {
        let total = report.epochs.len() as f64 * cfg.dynamic.solve_latency_s;
        println!(
            "solve overlap: {:.1}% of {:.1}s total solve time hidden behind GPU execution ({})",
            100.0 * report.solve_hidden_s() / total,
            total,
            cfg.dynamic.solve_mode.name(),
        );
    }
    if let Some(path) = span_path {
        write_spans(path, &rec, cfg.dynamic.window_s)?;
    }
    Ok(())
}

/// The constant-memory `dynamic` path (`--metrics-mode streaming`):
/// arrivals are generated lazily and every resolved request folds
/// straight into a GK quantile sketch, so memory stays flat no matter
/// how many requests the horizon produces.
fn run_dynamic_streaming(
    args: &Args,
    cfg: &ExperimentConfig,
    scheduler: &dyn BatchScheduler,
    allocator: &dyn Allocator,
    delay: &BatchDelayModel,
    quality: &dyn QualityModel,
    dyn_cfg: &DynamicConfig,
) -> Result<()> {
    if args.get("trace-out").is_some() {
        bail!("--trace-out needs --metrics-mode exact (streaming never materializes the trace)");
    }
    if args.get("trace-spans").is_some() {
        bail!("--trace-spans needs --metrics-mode exact (streaming keeps the NullSink fast path)");
    }
    println!(
        "dynamic scenario: {:?} rate {} Hz over {}s | epoch {}s max-batch {} | \
         streaming metrics (GK sketch, eps {})",
        cfg.arrival.process,
        cfg.arrival.rate_hz,
        cfg.arrival.horizon_s,
        cfg.dynamic.epoch_s,
        cfg.dynamic.max_batch,
        cfg.metrics.sketch_eps,
    );
    let stream = ArrivalStream::new(&cfg.scenario, &cfg.arrival, cfg.seed);
    let (bw, bits) = (stream.total_bandwidth_hz(), stream.content_bits());
    let report = simulate_dynamic_streaming(
        stream,
        bw,
        bits,
        scheduler,
        allocator,
        delay,
        quality,
        dyn_cfg,
        OutcomeAccumulator::streaming(cfg.metrics.sketch_eps),
    );
    let stats = report.stats();
    println!(
        "served {}/{} ({} dropped) over {} epochs, {:.1}s simulated | sketch support {}",
        report.served(),
        report.count(),
        report.dropped(),
        report.epochs,
        report.horizon_s,
        report.accumulator.support_len(),
    );
    println!(
        "mean FID {:.2} | outage rate {:.3} | e2e p50 {:.2}s p95 {:.2}s p99 {:.2}s | mean wait {:.2}s | throughput {:.2}/s | peak queue {}",
        stats.mean_quality,
        stats.outage_rate,
        stats.p50_e2e_s,
        stats.p95_e2e_s,
        stats.p99_e2e_s,
        stats.mean_wait_s,
        report.throughput_hz(),
        report.peak_queue_depth,
    );
    Ok(())
}

fn cmd_cluster(args: &Args) -> Result<()> {
    args.expect_only(&[
        "config",
        "servers",
        "router",
        "speed-min",
        "speed-max",
        "process",
        "rate",
        "horizon",
        "epoch-s",
        "max-batch",
        "window",
        "plan-horizon",
        "adaptive-horizon",
        "solve-latency",
        "solve-mode",
        "no-admission",
        "warm-start",
        "trace-spans",
        "scheduler",
        "allocator",
        "seed",
        "threads",
    ])?;
    let mut cfg = load_config(args)?;
    apply_dynamic_flags(args, &mut cfg)?;
    apply_cluster_flags(args, &mut cfg)?;
    cfg.validate()?;

    let scheduler = scheduler_from(args, &cfg)?;
    let pool = allocator_pool_from(args, cfg.cluster.servers)?;
    let quality = quality_model(&cfg)?;
    let delay = BatchDelayModel::new(cfg.delay.a, cfg.delay.b);
    let trace = ArrivalTrace::generate(&cfg.scenario, &cfg.arrival, cfg.seed);
    let mut cluster_cfg = ClusterConfig::from_settings(&cfg.cluster, &cfg.dynamic);
    cluster_cfg.dynamic.cache = cfg.cache;
    // Per-server solve fan-out (bit-identical at any count).
    cluster_cfg.dynamic.threads = threads_from(args, &cfg)?;
    println!(
        "cluster: {} servers (speeds {:?}) router={} | {:?} rate {} Hz over {}s | epoch {}s | \
         solve {} @ {}s",
        cluster_cfg.servers(),
        cluster_cfg.speeds.iter().map(|s| (s * 100.0).round() / 100.0).collect::<Vec<_>>(),
        cfg.cluster.router.name(),
        cfg.arrival.process,
        cfg.arrival.rate_hz,
        cfg.arrival.horizon_s,
        cfg.dynamic.epoch_s,
        cfg.dynamic.solve_mode.name(),
        cfg.dynamic.solve_latency_s,
    );
    println!(
        "{} arrivals (empirical rate {:.2} Hz); scheduler={} allocator={} ({} instance{})",
        trace.len(),
        trace.mean_rate_hz(),
        scheduler.name(),
        pool.get(0).name(),
        pool.len(),
        if pool.len() == 1 { "" } else { "s" }
    );
    let span_path = args.get("trace-spans");
    let mut rec = obs::Recorder::new();
    let mut null = obs::NullSink;
    let tracer: &mut dyn obs::TraceSink = if span_path.is_some() { &mut rec } else { &mut null };
    // The live-state router reads views only the event engine
    // publishes — through the sequential engine it would silently
    // degenerate to virtual JSQ. The zero-fault event engine is
    // bit-identical to `simulate_cluster` for every virtual-view
    // policy (tests/pipeline_equivalence.rs), so live routing runs
    // there and everything else keeps the sequential path.
    let view = if cfg.cluster.router == RouterKind::LiveState {
        let event_cfg = EventClusterConfig {
            speeds: &cluster_cfg.speeds,
            router: cfg.cluster.router,
            dynamic: cluster_cfg.dynamic,
            faults: &aigc_edge::faults::NO_FAULTS,
            migration: MigrationPolicyKind::None,
            resume_transfer_s: 0.0,
        };
        let report = simulate_event_cluster_pooled_traced(
            &trace,
            scheduler.as_ref(),
            &pool,
            &delay,
            quality.as_ref(),
            &event_cfg,
            tracer,
        );
        ClusterView {
            rows: report
                .servers
                .iter()
                .map(|s| (s.server, s.speed, report.server_stats(s.server)))
                .collect(),
            fleet: report.fleet_stats(),
            served: report.served(),
            total: report.outcomes.len(),
            mean_quality: report.mean_quality(),
            outage_rate: report.outage_rate(),
            epochs: report.total_epochs(),
            deferrals: report.total_deferrals(),
            peak_queue: report.peak_queue_depth(),
            horizon_s: report.horizon_s,
        }
    } else {
        let report = simulate_cluster_pooled_traced(
            &trace,
            scheduler.as_ref(),
            &pool,
            &delay,
            quality.as_ref(),
            &cluster_cfg,
            tracer,
        );
        ClusterView {
            rows: report.servers.iter().map(|s| (s.server, s.speed, s.stats())).collect(),
            fleet: report.fleet_stats(),
            served: report.served(),
            total: report.outcomes.len(),
            mean_quality: report.mean_quality(),
            outage_rate: report.outage_rate(),
            epochs: report.total_epochs(),
            deferrals: report.total_deferrals(),
            peak_queue: report.peak_queue_depth(),
            horizon_s: report.horizon_s,
        }
    };

    let mut table = aigc_edge::bench::TableWriter::new(
        "per-server serving summary",
        &["server", "speed", "assigned", "served", "mean FID", "outage", "p50 e2e", "p99 e2e"],
    );
    let stats_row = |tag: String, speed: String, stats: &OutcomeStats| {
        vec![
            tag,
            speed,
            stats.count.to_string(),
            stats.served.to_string(),
            format!("{:.1}", stats.mean_quality),
            format!("{:.3}", stats.outage_rate),
            format!("{:.2}", stats.p50_e2e_s),
            format!("{:.2}", stats.p99_e2e_s),
        ]
    };
    for (server, speed, stats) in &view.rows {
        table.row(&stats_row(server.to_string(), format!("{speed:.2}"), stats));
    }
    table.row(&stats_row("fleet".into(), "-".into(), &view.fleet));
    table.finish();
    println!(
        "served {}/{} | mean FID {:.2} | outage rate {:.3} | {} epochs across servers | \
         {} deferrals | peak queue {} | {:.1}s simulated",
        view.served,
        view.total,
        view.mean_quality,
        view.outage_rate,
        view.epochs,
        view.deferrals,
        view.peak_queue,
        view.horizon_s,
    );
    if let Some(path) = span_path {
        write_spans(path, &rec, cfg.dynamic.window_s)?;
    }
    Ok(())
}

/// The engine-agnostic slice of a cluster run that `cmd_cluster`
/// prints — filled from either the sequential or the event engine's
/// report, so the two paths cannot drift apart field-by-field.
struct ClusterView {
    /// Per-server (id, speed, resolved-request stats).
    rows: Vec<(usize, f64, OutcomeStats)>,
    fleet: OutcomeStats,
    served: usize,
    total: usize,
    mean_quality: f64,
    outage_rate: f64,
    epochs: usize,
    deferrals: usize,
    peak_queue: usize,
    horizon_s: f64,
}

fn cmd_faults(args: &Args) -> Result<()> {
    args.expect_only(&[
        "config",
        "servers",
        "router",
        "speed-min",
        "speed-max",
        "process",
        "rate",
        "horizon",
        "epoch-s",
        "max-batch",
        "window",
        "plan-horizon",
        "adaptive-horizon",
        "solve-latency",
        "solve-mode",
        "no-admission",
        "warm-start",
        "scheduler",
        "allocator",
        "seed",
        "threads",
        "migration",
        "transfer-s",
        "fault-mode",
        "mtbf",
        "mttr",
        "fault-seed",
        "down",
        "trace-spans",
    ])?;
    let mut cfg = load_config(args)?;
    apply_dynamic_flags(args, &mut cfg)?;
    apply_cluster_flags(args, &mut cfg)?;
    if let Some(name) = args.get("fault-mode") {
        cfg.faults.mode = FaultModeKind::from_name(name)?;
    }
    cfg.faults.mtbf_s = args.get_f64("mtbf", cfg.faults.mtbf_s)?;
    cfg.faults.mttr_s = args.get_f64("mttr", cfg.faults.mttr_s)?;
    cfg.faults.seed = args.get_u64("fault-seed", cfg.faults.seed)?;
    if let Some(spec) = args.get("down") {
        // an explicit interval list implies scheduled mode
        cfg.faults.down = FaultScript::parse_spec(spec)?;
        cfg.faults.mode = FaultModeKind::Scheduled;
    }
    if let Some(name) = args.get("migration") {
        cfg.migration.policy = MigrationPolicyKind::from_name(name)?;
    }
    cfg.migration.transfer_s = args.get_f64("transfer-s", cfg.migration.transfer_s)?;
    cfg.validate()?;

    let scheduler = scheduler_from(args, &cfg)?;
    let pool = allocator_pool_from(args, cfg.cluster.servers)?;
    let quality = quality_model(&cfg)?;
    let delay = BatchDelayModel::new(cfg.delay.a, cfg.delay.b);
    let trace = ArrivalTrace::generate(&cfg.scenario, &cfg.arrival, cfg.seed);
    let faults = cfg.faults.script(cfg.cluster.servers, cfg.arrival.horizon_s, cfg.seed)?;
    let speeds = aigc_edge::sim::server_speeds(
        cfg.cluster.servers,
        cfg.cluster.speed_min,
        cfg.cluster.speed_max,
    );
    let mut dynamic = DynamicConfig::from(&cfg.dynamic);
    dynamic.cache = cfg.cache;
    // Shared-freeze-instant solve fan-out (bit-identical at any count).
    dynamic.threads = threads_from(args, &cfg)?;
    let event_cfg = EventClusterConfig {
        speeds: &speeds,
        router: cfg.cluster.router,
        dynamic,
        faults: &faults,
        migration: cfg.migration.policy,
        resume_transfer_s: cfg.migration.transfer_s,
    };
    println!(
        "faults: {} servers router={} | mode={} ({} outages, {:.1}s scheduled downtime) | migration={}",
        event_cfg.servers(),
        cfg.cluster.router.name(),
        cfg.faults.mode.name(),
        event_cfg.faults.downs().len(),
        event_cfg.faults.total_downtime_s(),
        cfg.migration.policy.name(),
    );
    println!(
        "{} arrivals ({:?} rate {} Hz over {}s); scheduler={} allocator={} ({} instance{})",
        trace.len(),
        cfg.arrival.process,
        cfg.arrival.rate_hz,
        cfg.arrival.horizon_s,
        scheduler.name(),
        pool.get(0).name(),
        pool.len(),
        if pool.len() == 1 { "" } else { "s" }
    );
    let span_path = args.get("trace-spans");
    let mut rec = obs::Recorder::new();
    let mut null = obs::NullSink;
    let tracer: &mut dyn obs::TraceSink = if span_path.is_some() { &mut rec } else { &mut null };
    let report = simulate_event_cluster_pooled_traced(
        &trace,
        scheduler.as_ref(),
        &pool,
        &delay,
        quality.as_ref(),
        &event_cfg,
        tracer,
    );

    let mut table = aigc_edge::bench::TableWriter::new(
        "per-server serving summary (under failure injection)",
        &[
            "server", "speed", "down s", "assigned", "resolved", "served", "mean FID", "outage",
            "p99 e2e",
        ],
    );
    for s in &report.servers {
        let stats = report.server_stats(s.server);
        table.row(&[
            s.server.to_string(),
            format!("{:.2}", s.speed),
            format!("{:.1}", s.downtime_s),
            s.assigned_ids.len().to_string(),
            stats.count.to_string(),
            stats.served.to_string(),
            format!("{:.1}", stats.mean_quality),
            format!("{:.3}", stats.outage_rate),
            format!("{:.2}", stats.p99_e2e_s),
        ]);
    }
    let fleet = report.fleet_stats();
    table.row(&[
        "fleet".into(),
        "-".into(),
        "-".into(),
        report.outcomes.len().to_string(),
        fleet.count.to_string(),
        fleet.served.to_string(),
        format!("{:.1}", fleet.mean_quality),
        format!("{:.3}", fleet.outage_rate),
        format!("{:.2}", fleet.p99_e2e_s),
    ]);
    table.finish();
    println!(
        "served {}/{} | mean FID {:.2} | outage rate {:.3} | {} failures | {} migrated | \
         {} lost to failure | {:.1}s simulated",
        report.served(),
        report.outcomes.len(),
        report.mean_quality(),
        report.outage_rate(),
        report.failures(),
        report.migrated(),
        report.lost_to_failure(),
        report.horizon_s,
    );
    let rs = report.recovery_stats(cfg.dynamic.window_s);
    println!(
        "recovery: mean time-to-drain {:.2}s | post-failure p99 (deadline-censored) {:.2}s | \
         post-failure outage {:.3} over {} requests | {} checkpoint-resumed ({} steps salvaged)",
        rs.mean_time_to_drain_s,
        rs.post_failure_p99_s,
        rs.post_failure_outage_rate,
        rs.post_failure_count,
        rs.resumed,
        rs.recovered_steps,
    );
    if let Some(path) = span_path {
        write_spans(path, &rec, cfg.dynamic.window_s)?;
    }
    Ok(())
}

/// Persist a captured flight-recorder stream (`--trace-spans`) in the
/// columnar span format — emission order, which `aigc-edge trace`
/// audits — and print the derived telemetry summary.
fn write_spans(path: &str, rec: &obs::Recorder, window_s: f64) -> Result<()> {
    let bytes = obs::span::encode(&rec.events);
    std::fs::write(path, &bytes).with_context(|| format!("writing spans {path}"))?;
    println!("{} lifecycle events ({} bytes) written to {path}", rec.events.len(), bytes.len());
    let fleet = obs::telemetry::FleetTelemetry::from_events(&rec.events, window_s);
    print!("{}", fleet.summary());
    Ok(())
}

/// Offline span tooling: summarize, audit, and optionally export a
/// capture to a perfetto (chrome trace event) timeline. Exits nonzero
/// when the lifecycle audit finds violations, so CI can gate on it.
fn cmd_trace(args: &Args) -> Result<()> {
    args.expect_only(&["in", "perfetto", "window"])?;
    let path = args.get("in").context("trace needs --in <spans.bin>")?;
    let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
    let events = obs::span::decode(&bytes)?;
    println!("{path}: {} lifecycle events", events.len());
    print!("{}", obs::telemetry::kind_counts(&events));
    let window_s = args.get_f64("window", 30.0)?;
    let fleet = obs::telemetry::FleetTelemetry::from_events(&events, window_s);
    print!("{}", fleet.summary());
    let report = obs::audit::audit(&events);
    print!("{}", report.render());
    if let Some(out) = args.get("perfetto") {
        let json = obs::perfetto::export(&events);
        std::fs::write(out, &json).with_context(|| format!("writing {out}"))?;
        println!("perfetto timeline written to {out} (load at ui.perfetto.dev)");
    }
    if !report.is_clean() {
        bail!("span audit found {} lifecycle violation(s)", report.violations.len());
    }
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    args.expect_only(&["reps", "config"])?;
    let cfg = load_config(args)?;
    let reps = args.get_usize("reps", 20)?;
    let store = ArtifactStore::load(&cfg.artifacts_dir).context("loading artifacts")?;
    println!("platform: {}", store.platform());
    let fit = profile_batch_delay(&store, ProfileConfig { reps, ..Default::default() })?;
    let model = fit.model();
    println!("g(X) = aX + b fit over buckets {:?}", store.buckets());
    for (x, s) in &fit.samples {
        println!("  X={x:>3}: {:.5}s (fit {:.5}s)", s, model.g(*x));
    }
    println!("a = {:.6} s/task, b = {:.6} s/batch, R² = {:.4}", model.a, model.b, fit.fit.r2);
    Ok(())
}

fn cmd_perf(args: &Args) -> Result<()> {
    args.expect_only(&["config", "threads", "quick", "out", "seed"])?;
    let mut cfg = load_config(args)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    let threads = threads_from(args, &cfg)?;
    let quick = match args.get("quick") {
        None | Some("false") => false,
        Some("true") => true,
        Some(other) => bail!("--quick must be true or false, got '{other}'"),
    };
    let opts = bench::PerfOptions { threads, quick };
    println!(
        "perf harness: serial (1 thread) vs parallel ({} threads){}",
        aigc_edge::util::resolve_threads(threads),
        if quick { " — quick sizes" } else { "" },
    );
    let rows = bench::run_perf(&cfg, &opts);
    let mut table = aigc_edge::bench::TableWriter::new(
        "parallel solve fabric — wall-clock per hot loop",
        &["loop", "serial s", "parallel s", "speedup", "bit-identical"],
    );
    for r in &rows {
        table.row(&[
            r.loop_name.to_string(),
            format!("{:.4}", r.serial_s),
            format!("{:.4}", r.parallel_s),
            format!("{:.2}x", r.speedup()),
            r.bit_identical.to_string(),
        ]);
    }
    table.finish();
    if let Some(bad) = rows.iter().find(|r| !r.bit_identical) {
        bail!("{}: parallel output diverged from serial — determinism bug", bad.loop_name);
    }
    // Default to the invocation directory (run from the repo root to
    // track the trajectory in-tree); the compile-time checkout path is
    // only trusted by `cargo bench`, which runs where it built.
    let out = std::path::PathBuf::from(args.get_or("out", "BENCH_pr5.json"));
    bench::write_bench_json(&out, &rows, &opts)
        .with_context(|| format!("writing {}", out.display()))?;
    println!("perf trajectory written to {}", out.display());
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    args.expect_only(&["which", "reps", "config", "threads"])?;
    let mut cfg = load_config(args)?;
    // Sweep-cell fan-out (bit-identical at any count).
    cfg.perf.threads = threads_from(args, &cfg)?;
    let which = args.get_or("which", "all");
    let reps = args.get_usize("reps", 3)?;
    let want = |name: &str| which == "all" || which == name;
    if want("1a") {
        let store = ArtifactStore::load(&cfg.artifacts_dir).context("loading artifacts")?;
        bench::fig1a(&store, reps.max(5));
    }
    if want("1b") {
        bench::fig1b(&cfg);
    }
    if want("2a") {
        bench::fig2a(&cfg);
    }
    if want("2b") {
        bench::fig2b(&cfg, &[5, 10, 15, 20, 25, 30, 35, 40], reps);
    }
    if want("2c") {
        bench::fig2c(&cfg, &[3.0, 5.0, 7.0, 9.0, 11.0, 13.0, 15.0, 17.0, 19.0], reps);
    }
    if want("3") {
        bench::fig3_dynamic(&cfg, &[0.5, 1.0, 2.0, 4.0, 8.0, 12.0, 16.0, 20.0], 200.0);
    }
    if want("cluster") {
        bench::fig_cluster(&cfg, &[0.5, 1.0, 2.0, 4.0, 8.0, 12.0, 16.0, 20.0], 200.0);
    }
    if want("faults") {
        bench::fig_faults(&cfg, &[0.0, 0.5, 1.0, 2.0], 200.0);
    }
    if want("checkpoint") {
        bench::fig_checkpoint(&cfg, 200.0);
    }
    if want("pipeline") {
        bench::fig_pipeline(&cfg, &[0.0, 0.1, 0.25, 0.5], 200.0);
    }
    if want("cache") {
        bench::fig_cache(&cfg, &[0.6, 1.2, 1.8], &[8, 64], 200.0);
    }
    Ok(())
}
