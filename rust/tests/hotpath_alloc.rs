//! Hot-loop allocation guard (ISSUE 5): a counting global allocator
//! pins the zero-alloc scratch reuse in the two solve hot paths —
//! STACKING's per-`T*` grid trials and PSO's per-iteration swarm
//! update. Both must allocate O(1) amortized per solve: growing the
//! `T*` grid or the iteration budget by an order of magnitude may not
//! grow the allocation count with it.
//!
//! Everything runs inside ONE `#[test]` — the counter is process-wide,
//! and concurrent tests in this binary would pollute it.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use aigc_edge::bandwidth::{AllocationProblem, Allocator, PsoAllocator, PsoConfig};
use aigc_edge::channel::Link;
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::quality::PowerLawQuality;
use aigc_edge::scheduler::{BatchScheduler, Service, Stacking, StackingConfig};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs_during<R>(f: impl FnOnce() -> R) -> (usize, R) {
    let before = ALLOCS.load(Ordering::Relaxed);
    let r = f();
    (ALLOCS.load(Ordering::Relaxed) - before, r)
}

#[test]
fn solve_hot_loops_allocate_o1_per_epoch() {
    // ---- STACKING: allocation count must not scale with the T* grid ----
    // 12 services (below the stdlib sort's allocation threshold, like
    // every real epoch batch) with equal budgets: the winning schedule
    // is the same whatever the grid bound, so the only difference
    // between the two configs is ~10× more dry trials — which must be
    // allocation-free thanks to the shared TrialScratch.
    let services: Vec<Service> = (0..12).map(|i| Service::new(i, 8.0)).collect();
    let delay = BatchDelayModel::paper();
    let quality = PowerLawQuality::paper();
    let schedule_with_grid = |t_star_max: u32| {
        Stacking::new(StackingConfig { t_star_max: Some(t_star_max), ..Default::default() })
    };
    let small = schedule_with_grid(24);
    let large = schedule_with_grid(240);
    // warm-up (untimed): first calls touch lazy init paths
    small.schedule(&services, &delay, &quality);
    large.schedule(&services, &delay, &quality);
    let (small_allocs, small_sched) =
        allocs_during(|| small.schedule(&services, &delay, &quality));
    let (large_allocs, large_sched) =
        allocs_during(|| large.schedule(&services, &delay, &quality));
    assert_eq!(small_sched.steps, large_sched.steps, "equal-budget winner must not change");
    assert!(
        large_allocs <= small_allocs + 32,
        "10x the T* grid may not grow allocations: {small_allocs} -> {large_allocs}"
    );

    // ---- PSO: allocation count must not scale with iterations ----
    let problem = AllocationProblem::new(
        40_000.0,
        (0..6).map(|i| Link::new(5.0 + i as f64 * 0.5)).collect(),
    );
    let mut objective = |b: &[f64]| -> f64 { b.iter().map(|x| (x - 5_000.0).abs()).sum() };
    let pso_with_iters = |iterations: usize| {
        PsoAllocator::new(PsoConfig {
            particles: 8,
            iterations,
            patience: 0, // no early stop: the iteration counts really differ
            ..Default::default()
        })
    };
    let short = pso_with_iters(5);
    let long = pso_with_iters(50);
    // warm-up: builds each allocator's swarm scratch once
    short.allocate(&problem, &mut objective);
    long.allocate(&problem, &mut objective);
    let (short_allocs, a) = allocs_during(|| short.allocate(&problem, &mut objective));
    let (long_allocs, b) = allocs_during(|| long.allocate(&problem, &mut objective));
    assert_eq!(a.len(), b.len());
    assert!(
        long_allocs <= short_allocs + 16,
        "10x the PSO iterations may not grow allocations: {short_allocs} -> {long_allocs}"
    );
    // sanity: the steady-state solve is near-zero-alloc in absolute
    // terms, not just flat (scratch + the returned best position)
    assert!(long_allocs <= 24, "steady-state PSO solve allocates too much: {long_allocs}");
}
