//! Hot-loop allocation guard (ISSUE 5, extended by ISSUE 10): a
//! counting global allocator pins the zero-alloc scratch reuse in the
//! solve hot paths — STACKING's per-`T*` grid trials and PSO's
//! per-iteration swarm update — and in the route hot path (indexed
//! dispatch + virtual-queue charge). Each must allocate O(1) amortized
//! per unit of work: growing the `T*` grid, the iteration budget, or
//! the routed-arrival count by an order of magnitude may not grow the
//! allocation count with it.
//!
//! Everything runs inside ONE `#[test]` — the counter is process-wide,
//! and concurrent tests in this binary would pollute it.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use aigc_edge::bandwidth::{AllocationProblem, Allocator, PsoAllocator, PsoConfig};
use aigc_edge::cache::CacheSettings;
use aigc_edge::channel::Link;
use aigc_edge::config::{ArrivalProcessKind, ArrivalSettings, ExperimentConfig};
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::quality::PowerLawQuality;
use aigc_edge::routing::{route_arrivals, FleetIndex, RouteContext, RouterKind, ServerState};
use aigc_edge::scheduler::{BatchScheduler, Service, Stacking, StackingConfig};
use aigc_edge::sim::server_speeds;
use aigc_edge::trace::ArrivalTrace;

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs_during<R>(f: impl FnOnce() -> R) -> (usize, R) {
    let before = ALLOCS.load(Ordering::Relaxed);
    let r = f();
    (ALLOCS.load(Ordering::Relaxed) - before, r)
}

#[test]
fn hot_loops_allocate_o1_per_unit_of_work() {
    // ---- STACKING: allocation count must not scale with the T* grid ----
    // 12 services (below the stdlib sort's allocation threshold, like
    // every real epoch batch) with equal budgets: the winning schedule
    // is the same whatever the grid bound, so the only difference
    // between the two configs is ~10× more dry trials — which must be
    // allocation-free thanks to the shared TrialScratch.
    let services: Vec<Service> = (0..12).map(|i| Service::new(i, 8.0)).collect();
    let delay = BatchDelayModel::paper();
    let quality = PowerLawQuality::paper();
    let schedule_with_grid = |t_star_max: u32| {
        Stacking::new(StackingConfig { t_star_max: Some(t_star_max), ..Default::default() })
    };
    let small = schedule_with_grid(24);
    let large = schedule_with_grid(240);
    // warm-up (untimed): first calls touch lazy init paths
    small.schedule(&services, &delay, &quality);
    large.schedule(&services, &delay, &quality);
    let (small_allocs, small_sched) =
        allocs_during(|| small.schedule(&services, &delay, &quality));
    let (large_allocs, large_sched) =
        allocs_during(|| large.schedule(&services, &delay, &quality));
    assert_eq!(small_sched.steps, large_sched.steps, "equal-budget winner must not change");
    assert!(
        large_allocs <= small_allocs + 32,
        "10x the T* grid may not grow allocations: {small_allocs} -> {large_allocs}"
    );

    // ---- PSO: allocation count must not scale with iterations ----
    let problem = AllocationProblem::new(
        40_000.0,
        (0..6).map(|i| Link::new(5.0 + i as f64 * 0.5)).collect(),
    );
    let mut objective = |b: &[f64]| -> f64 { b.iter().map(|x| (x - 5_000.0).abs()).sum() };
    let pso_with_iters = |iterations: usize| {
        PsoAllocator::new(PsoConfig {
            particles: 8,
            iterations,
            patience: 0, // no early stop: the iteration counts really differ
            ..Default::default()
        })
    };
    let short = pso_with_iters(5);
    let long = pso_with_iters(50);
    // warm-up: builds each allocator's swarm scratch once
    short.allocate(&problem, &mut objective);
    long.allocate(&problem, &mut objective);
    let (short_allocs, a) = allocs_during(|| short.allocate(&problem, &mut objective));
    let (long_allocs, b) = allocs_during(|| long.allocate(&problem, &mut objective));
    assert_eq!(a.len(), b.len());
    assert!(
        long_allocs <= short_allocs + 16,
        "10x the PSO iterations may not grow allocations: {short_allocs} -> {long_allocs}"
    );
    // sanity: the steady-state solve is near-zero-alloc in absolute
    // terms, not just flat (scratch + the returned best position)
    assert!(long_allocs <= 24, "steady-state PSO solve allocates too much: {long_allocs}");

    // ---- routing: allocation count must not scale with arrivals ----
    // The indexed route hot path reuses the fleet, the index, the
    // cache-aware scratch/owner pools and the output buffer, so after
    // a warm-up window a 10x longer arrival batch may not grow the
    // allocation count — for every routing policy. 6 servers keep each
    // index BTree inside a single (never-split, never-freed) root
    // node, and ~50% utilization (10 Hz against ~19.8 req/s of fleet
    // capacity) holds the virtual-queue deques at a steady-state
    // high-water mark. Marks ride along so the cache-aware shadow
    // machinery runs too; the small universe (4 prompts x 2 models) is
    // fully seen during warm-up, after which the owner maps stop
    // growing.
    let cfg = ExperimentConfig::paper();
    let arrival = ArrivalSettings {
        process: ArrivalProcessKind::Poisson,
        rate_hz: 10.0,
        burst_rate_hz: 10.0,
        period_s: 60.0,
        duty: 0.5,
        horizon_s: 400.0,
        max_requests: 1250,
        prompt_universe: 4,
        zipf_s: 1.2,
        models: 2,
    };
    let trace = ArrivalTrace::generate(&cfg.scenario, &arrival, 42);
    assert_eq!(trace.len(), 1250, "horizon must fill the request cap");
    let ctx = RouteContext {
        total_bandwidth_hz: trace.total_bandwidth_hz,
        content_bits: trace.content_bits,
    };
    let speeds = server_speeds(6, 0.5, 2.0);
    for kind in RouterKind::with_live().into_iter().chain([RouterKind::CacheAware]) {
        let cache = CacheSettings { enabled: true, capacity: 16, ..CacheSettings::default() };
        let mut router = kind.build_with_cache(delay, cache);
        let mut fleet = ServerState::fleet(&speeds);
        let mut index = FleetIndex::new(&fleet);
        let mut assignment = Vec::with_capacity(trace.len());
        // warm-up: queue deques, index roots, shadow caches, scratch
        route_arrivals(
            &trace.arrivals[..150],
            &mut fleet,
            router.as_mut(),
            &delay,
            &ctx,
            &mut index,
            &mut assignment,
        );
        let (one_allocs, _) = allocs_during(|| {
            route_arrivals(
                &trace.arrivals[150..250],
                &mut fleet,
                router.as_mut(),
                &delay,
                &ctx,
                &mut index,
                &mut assignment,
            )
        });
        let (ten_allocs, _) = allocs_during(|| {
            route_arrivals(
                &trace.arrivals[250..1250],
                &mut fleet,
                router.as_mut(),
                &delay,
                &ctx,
                &mut index,
                &mut assignment,
            )
        });
        assert_eq!(assignment.len(), trace.len(), "{}: every arrival routed", kind.name());
        assert!(
            ten_allocs <= one_allocs + 32,
            "{}: 10x the routed arrivals may not grow allocations: {one_allocs} -> {ten_allocs}",
            kind.name()
        );
    }
}
