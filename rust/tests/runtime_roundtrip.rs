//! Integration: the full AOT round-trip. Executes every artifact bucket
//! through PJRT against the golden vectors exported by `aot.py`
//! (inputs + the in-process JAX model's outputs). This is the numeric
//! proof that the L1/L2 Python stack and the L3 Rust runtime compute the
//! same function.

use std::path::PathBuf;

use aigc_edge::config::default_artifacts_dir;
use aigc_edge::runtime::{ArtifactStore, BatchInput, DenoiseExecutor};

fn artifacts() -> Option<PathBuf> {
    let dir = default_artifacts_dir();
    dir.join("manifest.json").exists().then_some(dir)
}

/// Layout per aot.py: f32 x[B*D] | i32 t_cur[B] | i32 t_prev[B] | f32 expected[B*D].
fn read_golden(path: &PathBuf, b: usize, d: usize) -> (Vec<f32>, Vec<i32>, Vec<i32>, Vec<f32>) {
    let raw = std::fs::read(path).expect("golden file");
    assert_eq!(raw.len(), 4 * (b * d + b + b + b * d), "golden size mismatch");
    let f32_at = |offset: usize, n: usize| -> Vec<f32> {
        raw[offset..offset + 4 * n]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    };
    let i32_at = |offset: usize, n: usize| -> Vec<i32> {
        raw[offset..offset + 4 * n]
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    };
    let mut off = 0;
    let x = f32_at(off, b * d);
    off += 4 * b * d;
    let t_cur = i32_at(off, b);
    off += 4 * b;
    let t_prev = i32_at(off, b);
    off += 4 * b;
    let expected = f32_at(off, b * d);
    (x, t_cur, t_prev, expected)
}

#[test]
fn golden_vectors_roundtrip_every_bucket() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let store = ArtifactStore::load(&dir).unwrap();
    let manifest = store.manifest().clone();
    let d = manifest.data_dim;
    assert!(!manifest.golden_files.is_empty(), "no golden files in manifest");
    let mut exec = DenoiseExecutor::new(&store);

    for (&bucket, file) in &manifest.golden_files {
        let b = bucket as usize;
        let (x, t_cur, t_prev, expected) = read_golden(&dir.join(file), b, d);
        let tasks: Vec<BatchInput> = (0..b)
            .map(|i| BatchInput {
                latent: &x[i * d..(i + 1) * d],
                t_cur: t_cur[i],
                t_prev: t_prev[i],
            })
            .collect();
        let out = exec.step(&tasks).unwrap();
        assert_eq!(out.bucket, bucket);
        let mut worst = 0f32;
        for i in 0..b {
            for j in 0..d {
                let got = out.latents[i][j];
                let want = expected[i * d + j];
                // NB: compare via explicit NaN check — f32::max silently
                // drops NaN operands, which once masked a real failure.
                assert!(got.is_finite(), "bucket {bucket}: NaN at ({i},{j})");
                worst = worst.max((got - want).abs());
            }
        }
        assert!(worst < 1e-3, "bucket {bucket}: max abs diff {worst}");
        println!("bucket {bucket:3}: max abs diff {worst:.2e} OK");
    }
}

/// Run a full DDIM chain through the real artifacts; returns the mean
/// L2 norm of the resulting batch.
fn chain_mean_norm(exec: &mut DenoiseExecutor, d: usize, n_train: i32, steps: usize) -> f64 {
    let mut rng = aigc_edge::util::Pcg64::seeded(1234);
    let batch = 8usize;
    let mut latents: Vec<Vec<f32>> =
        (0..batch).map(|_| (0..d).map(|_| rng.normal() as f32).collect()).collect();
    let ts: Vec<i32> = (0..=steps)
        .map(|i| ((n_train as f64) * (1.0 - i as f64 / steps as f64)).round() as i32)
        .collect();
    for w in ts.windows(2) {
        let (cur, prev) = (w[0], w[1]);
        let tasks: Vec<BatchInput> =
            latents.iter().map(|l| BatchInput { latent: l, t_cur: cur, t_prev: prev }).collect();
        latents = exec.step(&tasks).unwrap().latents;
    }
    assert!(latents.iter().flatten().all(|v| v.is_finite()));
    latents
        .iter()
        .map(|l| l.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt())
        .sum::<f64>()
        / batch as f64
}

#[test]
fn full_ddim_chain_quality_improves_with_steps() {
    // The premise of Fig. 1b, exercised end-to-end through the real
    // artifacts: a longer DDIM chain lands closer to the data manifold
    // (mean norm ≈ 3.4) than a shorter one. (The in-process JAX model
    // gives ~34 / ~22 / ~15 for 4 / 8 / 16 steps — the Rust runtime must
    // reproduce that ordering.)
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let store = ArtifactStore::load(&dir).unwrap();
    let d = store.manifest().data_dim;
    let n_train = store.manifest().num_train_steps as i32;
    let mut exec = DenoiseExecutor::new(&store);

    let n4 = chain_mean_norm(&mut exec, d, n_train, 4);
    let n8 = chain_mean_norm(&mut exec, d, n_train, 8);
    let n16 = chain_mean_norm(&mut exec, d, n_train, 16);
    assert!(n8 < n4, "norms: 4-step {n4:.2}, 8-step {n8:.2}");
    assert!(n16 < n8, "norms: 8-step {n8:.2}, 16-step {n16:.2}");
    // Cross-language pin: 8-step chain ≈ 22 in the JAX model.
    assert!((10.0..40.0).contains(&n8), "8-step norm {n8:.2} out of family");
}
