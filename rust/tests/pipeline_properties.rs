//! Property suite for the pipelined epoch lifecycle (ISSUE 4):
//! randomized traces and lifecycle settings through `sim::dynamic` and
//! `sim::event`, asserting the dominance and determinism invariants
//! the pipeline must never break.
//!
//! Invariants (each over randomized runs):
//! * **aggregate dominance** — at equal nonzero solve latency, the
//!   pipelined lifecycle's mean deadline-censored end-to-end delay
//!   never exceeds the synchronous one's (dropped requests charge
//!   their full relative deadline, so trading drops for speed cannot
//!   flatter the synchronous mode);
//! * **request-for-request dominance** — in the clean regime where
//!   both lifecycles serve every request without deferrals and every
//!   solve sees a planning-horizon-clamped residual (epoch memberships
//!   and solves are then provably identical), every single request
//!   resolves in the pipelined run no later than in the synchronous
//!   run;
//! * **hidden-time accounting** — per epoch, `0 ≤ hidden ≤ latency`,
//!   and the pipelined run hides time only when the GPU was busy;
//! * **determinism** — identical seeds replay bit-identically, and
//!   per-server warm-start allocator pools replay bit-identically from
//!   fresh pools (the PR-3 shared-allocator caveat is gone: with
//!   per-server pools, the event engine and the sequential cluster
//!   coincide bitwise even under warm-start PSO).

use aigc_edge::bandwidth::{Allocator, AllocatorPool, EqualAllocator, PsoAllocator, PsoConfig};
use aigc_edge::config::{ArrivalProcessKind, ArrivalSettings, ExperimentConfig};
use aigc_edge::coordinator::SolveMode;
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::faults::{MigrationPolicyKind, NO_FAULTS};
use aigc_edge::prop_assert;
use aigc_edge::quality::PowerLawQuality;
use aigc_edge::routing::RouterKind;
use aigc_edge::scheduler::Stacking;
use aigc_edge::sim::{
    server_speeds, simulate_cluster_pooled, simulate_dynamic, simulate_event_cluster_pooled,
    ClusterConfig, Disposition, DynamicConfig, DynamicReport, EventClusterConfig,
};
use aigc_edge::trace::ArrivalTrace;
use aigc_edge::util::prop::{forall, Gen};

fn random_trace(g: &mut Gen, deadline_lo: f64, rate_lo: f64, rate_hi: f64) -> ArrivalTrace {
    let mut scenario = ExperimentConfig::paper().scenario;
    scenario.deadline_lo = deadline_lo;
    scenario.deadline_hi = deadline_lo + g.f64_in(3.0, 10.0);
    let arrival = ArrivalSettings {
        process: ArrivalProcessKind::Poisson,
        rate_hz: g.f64_in(rate_lo, rate_hi),
        burst_rate_hz: rate_hi,
        period_s: 60.0,
        duty: 0.5,
        horizon_s: g.f64_in(20.0, 40.0),
        max_requests: 0,
        prompt_universe: 1,
        zipf_s: 1.0,
        models: 1,
    };
    ArrivalTrace::generate(&scenario, &arrival, g.u64())
}

fn run_dynamic(trace: &ArrivalTrace, cfg: &DynamicConfig) -> DynamicReport {
    simulate_dynamic(
        trace,
        &Stacking::default(),
        &EqualAllocator,
        &BatchDelayModel::paper(),
        &PowerLawQuality::paper(),
        cfg,
    )
}

/// The clean-regime check: every request served, never deferred, and
/// resolved with at least `plan_horizon_s` of residual budget — then
/// every epoch solve saw horizon-clamped (identical) deadlines, so
/// memberships and plans coincide across lifecycles and only the
/// batch-start instants differ.
fn clean_regime(report: &DynamicReport, cfg: &DynamicConfig) -> bool {
    report.outcomes.iter().all(|o| {
        o.disposition == Disposition::Served
            && o.deferrals == 0
            && o.wait_s + cfg.plan_horizon_s <= o.deadline_s
    })
}

#[test]
fn pipelined_never_loses_to_synchronous_on_censored_delay() {
    let mut request_level_hits = 0u32;
    let mut strict_wins = 0u32;
    forall("pipelined vs synchronous dominance", 25, |g| {
        // Generous deadlines and light-to-heavy Poisson load; the
        // solve latency stays below the epoch length.
        let trace = random_trace(g, 10.0, 1.0, 8.0);
        if trace.is_empty() {
            return true;
        }
        let latency = *g.pick(&[0.05, 0.1, 0.2, 0.3]);
        let base = DynamicConfig { solve_latency_s: latency, ..DynamicConfig::default() };
        let pipelined =
            run_dynamic(&trace, &DynamicConfig { solve_mode: SolveMode::Pipelined, ..base });
        let sync =
            run_dynamic(&trace, &DynamicConfig { solve_mode: SolveMode::Synchronous, ..base });

        // Aggregate dominance, always — with a small absolute slack:
        // once timelines diverge, epoch memberships can too (the
        // earlier-closing pipelined epoch may push a boundary arrival
        // to its next epoch), so exact dominance is only a theorem in
        // the clean regime below. The slack bounds what one boundary
        // flip can cost the mean on the shortest generated traces
        // while still catching any real regression (the synchronous
        // mode pays the full solve latency per backlogged epoch).
        let (p, s) = (pipelined.mean_e2e_censored_s(), sync.mean_e2e_censored_s());
        prop_assert!(g, p <= s + 0.1, "pipelined censored mean {p} > synchronous {s} + slack");
        if p + 1e-9 < s {
            strict_wins += 1;
        }

        // hidden-time accounting, always
        for e in &pipelined.epochs {
            prop_assert!(
                g,
                (0.0..=latency + 1e-12).contains(&e.solve_hidden_s),
                "hidden {} outside [0, {latency}]",
                e.solve_hidden_s
            );
        }
        prop_assert!(g, sync.solve_hidden_s() == 0.0, "synchronous hid solve time");

        // request-for-request dominance in the clean regime
        if clean_regime(&pipelined, &base) && clean_regime(&sync, &base) {
            request_level_hits += 1;
            for (a, b) in pipelined.outcomes.iter().zip(&sync.outcomes) {
                prop_assert!(
                    g,
                    a.resolved_s <= b.resolved_s + 1e-9,
                    "request {} resolves at {} pipelined vs {} synchronous",
                    a.id,
                    a.resolved_s,
                    b.resolved_s
                );
            }
        }
        true
    });
    assert!(
        request_level_hits > 0,
        "no iteration reached the clean request-for-request regime — loosen the generator"
    );
    assert!(
        strict_wins > 0,
        "no iteration showed a strict pipelined win — the load range never backlogged the GPU"
    );
}

#[test]
fn per_server_allocator_replay_is_seed_deterministic() {
    forall("per-server warm-start pool replay", 12, |g| {
        let trace = random_trace(g, 6.0, 2.0, 8.0);
        if trace.is_empty() {
            return true;
        }
        let servers = g.usize_in(2, 4);
        let speeds = server_speeds(servers, 0.6, 1.6);
        let dynamic = DynamicConfig {
            solve_latency_s: *g.pick(&[0.0, 0.15]),
            ..DynamicConfig::default()
        };
        let fresh_pool = || {
            AllocatorPool::per_server(servers, |_| {
                Box::new(PsoAllocator::new(PsoConfig {
                    particles: 6,
                    iterations: 6,
                    patience: 3,
                    warm_start: true,
                    ..Default::default()
                })) as Box<dyn Allocator>
            })
        };
        let event_cfg = EventClusterConfig {
            speeds: &speeds,
            router: RouterKind::JoinShortestQueue,
            dynamic,
            faults: &NO_FAULTS,
            migration: MigrationPolicyKind::None,
            resume_transfer_s: 0.0,
        };
        let run_event = |pool: &AllocatorPool| {
            simulate_event_cluster_pooled(
                &trace,
                &Stacking::default(),
                pool,
                &BatchDelayModel::paper(),
                &PowerLawQuality::paper(),
                &event_cfg,
            )
        };
        // fresh-pool replay is bit-identical
        let a = run_event(&fresh_pool());
        let b = run_event(&fresh_pool());
        prop_assert!(g, a.assignment == b.assignment, "assignments diverged on replay");
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            prop_assert!(
                g,
                x.quality.to_bits() == y.quality.to_bits()
                    && x.resolved_s.to_bits() == y.resolved_s.to_bits(),
                "request {} diverged on warm-start replay",
                x.id
            );
        }

        // with per-server instances, the shared-clock engine and the
        // sequential cluster agree bitwise even under warm-start PSO —
        // the PR-3 shared-allocator caveat is gone
        let cluster_cfg = ClusterConfig { speeds, router: RouterKind::JoinShortestQueue, dynamic };
        let seq = simulate_cluster_pooled(
            &trace,
            &Stacking::default(),
            &fresh_pool(),
            &BatchDelayModel::paper(),
            &PowerLawQuality::paper(),
            &cluster_cfg,
        );
        prop_assert!(g, a.assignment == seq.assignment, "engines diverged on dispatch");
        for (x, y) in a.outcomes.iter().zip(&seq.outcomes) {
            prop_assert!(
                g,
                x.quality.to_bits() == y.quality.to_bits()
                    && x.resolved_s.to_bits() == y.resolved_s.to_bits()
                    && x.steps == y.steps,
                "request {} diverged across engines under per-server warm starts",
                x.id
            );
        }
        true
    });
}
