//! Property suite for the content-addressed generation cache
//! (ISSUE 9): randomized marked traces, fleets, cache settings and
//! fault scripts through all three engines, asserting the invariants
//! the cache must never break.
//!
//! Invariants (each over ≥ 60 randomized runs):
//! * **bitwise invisibility** — with the cache disabled (the default),
//!   a prompt-marked trace and its mark-stripped twin produce
//!   bit-identical reports on `simulate_dynamic`, `simulate_cluster`
//!   and `simulate_event_cluster` across every router and fault
//!   script, and every cache counter stays zero;
//! * **hit determinism** — identical seeds (trace + fleet + cache +
//!   faults) replay cache-enabled runs bit-identically, hits included;
//! * **census conservation** — with hits in the mix every arrival
//!   still resolves exactly once, `ServedFromCache` outcomes bypass
//!   the epoch (zero wait, nonzero steps, a real mark), and the hit
//!   counter equals the `ServedFromCache` census even under faults
//!   (a hit resolves at the arrival instant, so a later death cannot
//!   retract it);
//! * **bounded eviction** — a `GenCache` never holds more than
//!   `capacity` entries at any instant, under either eviction policy,
//!   and its counters balance (`insertions - evictions == len`).

use aigc_edge::bandwidth::EqualAllocator;
use aigc_edge::cache::{CacheSettings, CacheStats, EvictionKind, GenCache};
use aigc_edge::config::{ArrivalProcessKind, ArrivalSettings, ExperimentConfig};
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::faults::{FaultScript, MigrationPolicyKind};
use aigc_edge::prop_assert;
use aigc_edge::quality::PowerLawQuality;
use aigc_edge::routing::RouterKind;
use aigc_edge::scheduler::Stacking;
use aigc_edge::sim::{
    simulate_cluster, simulate_dynamic, simulate_event_cluster, ClusterConfig, Disposition,
    DynamicConfig, EventClusterConfig, EventReport, RequestOutcome,
};
use aigc_edge::trace::{ArrivalTrace, PromptMark};
use aigc_edge::util::prop::{forall, Gen};

/// A random prompt-marked trace: skewed popularity over a small
/// universe so cache-enabled runs actually hit.
fn random_marked_trace(g: &mut Gen) -> ArrivalTrace {
    let mut scenario = ExperimentConfig::paper().scenario;
    scenario.deadline_lo = g.f64_in(1.0, 6.0);
    scenario.deadline_hi = scenario.deadline_lo + g.f64_in(1.0, 12.0);
    let burst = g.bool();
    let rate = g.f64_in(1.0, 8.0);
    let arrival = ArrivalSettings {
        process: if burst { ArrivalProcessKind::Burst } else { ArrivalProcessKind::Poisson },
        rate_hz: rate,
        burst_rate_hz: rate * g.f64_in(1.0, 3.0),
        period_s: g.f64_in(2.0, 15.0),
        duty: g.f64_in(0.1, 1.0),
        horizon_s: g.f64_in(4.0, 12.0),
        max_requests: 0,
        prompt_universe: g.usize_in(2, 24),
        zipf_s: g.f64_in(0.4, 2.0),
        models: g.usize_in(1, 3) as u32,
    };
    ArrivalTrace::generate(&scenario, &arrival, g.u64())
}

/// The same trace with every prompt mark erased — what the pre-cache
/// codebase would have generated.
fn strip_marks(trace: &ArrivalTrace) -> ArrivalTrace {
    let mut t = trace.clone();
    for a in &mut t.arrivals {
        a.mark = PromptMark::ZERO;
    }
    t
}

/// Random enabled cache settings (capacity ≥ 1 so hits are possible).
fn random_cache(g: &mut Gen) -> CacheSettings {
    CacheSettings {
        enabled: true,
        capacity: g.usize_in(1, 48),
        eviction: if g.bool() { EvictionKind::Clock } else { EvictionKind::SeededRandom },
        model_slots: g.usize_in(1, 3),
        load_delay_s: g.f64_in(0.0, 1.0),
        seed: g.u64(),
    }
}

/// Every router, including the cache-aware one (excluded from
/// `RouterKind::all()` because it is pointless on unmarked traces —
/// here the traces are marked).
fn random_router(g: &mut Gen) -> RouterKind {
    let mut pool = RouterKind::with_live().to_vec();
    pool.push(RouterKind::CacheAware);
    *g.pick(&pool)
}

/// A random fault script over the trace span (sometimes empty).
fn random_faults(g: &mut Gen, servers: usize, horizon_s: f64) -> FaultScript {
    if g.f64_in(0.0, 1.0) < 0.2 {
        return FaultScript::empty();
    }
    let mtbf = g.f64_in(2.0, 30.0);
    let mttr = g.f64_in(0.5, 10.0);
    FaultScript::random(servers, horizon_s * 1.2, mtbf, mttr, g.u64())
}

fn run_event(trace: &ArrivalTrace, cfg: &EventClusterConfig) -> EventReport {
    simulate_event_cluster(
        trace,
        &Stacking::default(),
        &EqualAllocator,
        &BatchDelayModel::paper(),
        &PowerLawQuality::paper(),
        cfg,
    )
}

/// Bitwise comparison of two outcome vectors; `prop_assert!` returns
/// `false` out of this helper, so call sites must forward the result.
fn outcomes_bitwise(g: &mut Gen, a: &[RequestOutcome], b: &[RequestOutcome], ctx: &str) -> bool {
    prop_assert!(g, a.len() == b.len(), "{ctx}: outcome count {} vs {}", a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        prop_assert!(g, x.id == y.id, "{ctx}: id {} vs {}", x.id, y.id);
        prop_assert!(g, x.disposition == y.disposition, "{ctx}: disposition {}", x.id);
        prop_assert!(g, x.steps == y.steps, "{ctx}: steps {}", x.id);
        prop_assert!(g, x.met == y.met, "{ctx}: met {}", x.id);
        prop_assert!(g, x.deferrals == y.deferrals, "{ctx}: deferrals {}", x.id);
        prop_assert!(g, x.recovered_steps == y.recovered_steps, "{ctx}: recovered {}", x.id);
        prop_assert!(g, x.quality.to_bits() == y.quality.to_bits(), "{ctx}: quality {}", x.id);
        prop_assert!(g, x.e2e_s.to_bits() == y.e2e_s.to_bits(), "{ctx}: e2e {}", x.id);
        prop_assert!(g, x.wait_s.to_bits() == y.wait_s.to_bits(), "{ctx}: wait {}", x.id);
        prop_assert!(g, x.resolved_s.to_bits() == y.resolved_s.to_bits(), "{ctx}: t {}", x.id);
    }
    true
}

#[test]
fn disabled_cache_is_bitwise_invisible_on_every_engine() {
    forall("cache-off bitwise invisibility", 60, |g: &mut Gen| {
        let marked = random_marked_trace(g);
        let stripped = strip_marks(&marked);
        let router = random_router(g);
        let n = g.usize_in(1, 4);
        let speeds = g.vec_of(n, |g| g.f64_in(0.4, 2.0));
        // DynamicConfig::default() carries CacheSettings::default(),
        // which is disabled — exactly the pre-cache position.
        let dynamic = DynamicConfig::default();

        // single-server engine
        let sched = Stacking::default();
        let delay = BatchDelayModel::paper();
        let quality = PowerLawQuality::paper();
        let dm = simulate_dynamic(&marked, &sched, &EqualAllocator, &delay, &quality, &dynamic);
        let ds = simulate_dynamic(&stripped, &sched, &EqualAllocator, &delay, &quality, &dynamic);
        if !outcomes_bitwise(g, &dm.outcomes, &ds.outcomes, "dynamic") {
            return false;
        }
        prop_assert!(g, dm.cache_stats == CacheStats::default(), "dynamic cache counters");
        prop_assert!(g, dm.horizon_s.to_bits() == ds.horizon_s.to_bits(), "dynamic horizon");

        // sharded cluster engine
        let cluster = ClusterConfig { speeds: speeds.clone(), router, dynamic };
        let cm = simulate_cluster(&marked, &sched, &EqualAllocator, &delay, &quality, &cluster);
        let cs = simulate_cluster(&stripped, &sched, &EqualAllocator, &delay, &quality, &cluster);
        if !outcomes_bitwise(g, &cm.outcomes, &cs.outcomes, "cluster") {
            return false;
        }
        prop_assert!(g, cm.assignment == cs.assignment, "cluster assignment");
        prop_assert!(g, cm.cache_stats() == CacheStats::default(), "cluster cache counters");

        // fault-aware event engine
        let faults = random_faults(g, n, marked.duration_s());
        let migration = *g.pick(&MigrationPolicyKind::all());
        let ecfg = EventClusterConfig {
            speeds: &speeds,
            router,
            dynamic,
            faults: &faults,
            migration,
            resume_transfer_s: g.f64_in(0.0, 1.0),
        };
        let em = run_event(&marked, &ecfg);
        let es = run_event(&stripped, &ecfg);
        if !outcomes_bitwise(g, &em.outcomes, &es.outcomes, "event") {
            return false;
        }
        prop_assert!(g, em.assignment == es.assignment, "event assignment");
        prop_assert!(g, em.horizon_s.to_bits() == es.horizon_s.to_bits(), "event horizon");
        prop_assert!(g, em.served_from_cache() == 0, "cache-off served hits");
        prop_assert!(g, em.cache_stats() == CacheStats::default(), "event cache counters");
        true
    });
}

#[test]
fn enabled_cache_replays_bitwise_per_seed() {
    forall("cache hit determinism", 60, |g: &mut Gen| {
        let trace = random_marked_trace(g);
        let n = g.usize_in(1, 4);
        let speeds = g.vec_of(n, |g| g.f64_in(0.4, 2.0));
        let faults = random_faults(g, n, trace.duration_s());
        let dynamic = DynamicConfig { cache: random_cache(g), ..DynamicConfig::default() };
        let cfg = EventClusterConfig {
            speeds: &speeds,
            router: random_router(g),
            dynamic,
            faults: &faults,
            migration: *g.pick(&MigrationPolicyKind::all()),
            resume_transfer_s: g.f64_in(0.0, 1.0),
        };
        let a = run_event(&trace, &cfg);
        let b = run_event(&trace, &cfg);
        if !outcomes_bitwise(g, &a.outcomes, &b.outcomes, "replay") {
            return false;
        }
        prop_assert!(g, a.assignment == b.assignment, "assignment replay");
        prop_assert!(g, a.horizon_s.to_bits() == b.horizon_s.to_bits(), "horizon replay");
        prop_assert!(g, a.cache_stats() == b.cache_stats(), "cache counter replay");
        prop_assert!(g, a.served_from_cache() == b.served_from_cache(), "hit census replay");
        true
    });
}

#[test]
fn census_conserves_with_cache_hits_in_the_mix() {
    forall("cache census conservation", 80, |g: &mut Gen| {
        let trace = random_marked_trace(g);
        let n = g.usize_in(1, 4);
        let speeds = g.vec_of(n, |g| g.f64_in(0.4, 2.0));
        let faults = random_faults(g, n, trace.duration_s());
        let dynamic = DynamicConfig { cache: random_cache(g), ..DynamicConfig::default() };
        let cfg = EventClusterConfig {
            speeds: &speeds,
            router: random_router(g),
            dynamic,
            faults: &faults,
            migration: *g.pick(&MigrationPolicyKind::all()),
            resume_transfer_s: g.f64_in(0.0, 1.0),
        };
        let report = run_event(&trace, &cfg);
        prop_assert!(g, report.outcomes.len() == trace.len(), "outcome count");
        prop_assert!(
            g,
            report.served() + report.dropped() == trace.len(),
            "served {} + dropped {} != {}",
            report.served(),
            report.dropped(),
            trace.len()
        );
        // every id resolved at most once, fleet-wide, hits included
        let mut counts = vec![0usize; trace.len()];
        for s in &report.servers {
            for &id in &s.resolved_ids {
                prop_assert!(g, id < trace.len(), "tombstone leaked: {id}");
                counts[id] += 1;
            }
        }
        for (id, &c) in counts.iter().enumerate() {
            prop_assert!(g, c <= 1, "request {id} resolved by {c} servers");
        }
        // a hit resolves at its arrival instant, so a later server
        // death can never retract it: the hit counter and the
        // ServedFromCache census agree even under faults
        let stats = report.cache_stats();
        prop_assert!(
            g,
            stats.hits as usize == report.served_from_cache(),
            "hits {} vs census {}",
            stats.hits,
            report.served_from_cache()
        );
        let mut per_server = CacheStats::default();
        for s in &report.servers {
            per_server.merge(&s.cache_stats);
        }
        prop_assert!(g, per_server == stats, "fleet stats != sum of per-server stats");
        for o in &report.outcomes {
            if o.disposition != Disposition::ServedFromCache {
                continue;
            }
            let a = &trace.arrivals[o.id];
            prop_assert!(g, !a.mark.is_zero(), "hit {} on an unmarked arrival", o.id);
            prop_assert!(g, o.wait_s == 0.0, "hit {} waited {}", o.id, o.wait_s);
            prop_assert!(g, o.steps > 0, "hit {} served zero steps", o.id);
            prop_assert!(g, o.recovered_steps == 0, "hit {} salvaged steps", o.id);
            prop_assert!(g, o.disposition.is_served(), "hit {} not counted served", o.id);
            let span = o.resolved_s - o.arrival_s;
            prop_assert!(g, (span - o.e2e_s).abs() < 1e-9, "hit {} e2e mismatch", o.id);
        }
        true
    });
}

#[test]
fn eviction_never_exceeds_capacity() {
    forall("bounded eviction", 150, |g: &mut Gen| {
        let capacity = g.usize_in(0, 16);
        let eviction = if g.bool() { EvictionKind::Clock } else { EvictionKind::SeededRandom };
        let mut cache = GenCache::new(capacity, eviction, g.u64());
        let ops = g.usize_in(1, 200);
        for _ in 0..ops {
            let mark = PromptMark {
                model: g.usize_in(0, 2) as u32,
                prompt: g.usize_in(1, 24) as u32,
            };
            if g.bool() {
                let steps = g.usize_in(1, 50) as u32;
                cache.insert(mark, steps);
                if capacity > 0 {
                    prop_assert!(g, cache.contains(mark), "fresh insert evicted itself");
                    prop_assert!(g, cache.lookup(mark).is_some(), "fresh insert not found");
                }
            } else {
                let hit = cache.lookup(mark);
                prop_assert!(g, hit.is_some() == cache.contains(mark), "lookup vs contains");
            }
            prop_assert!(
                g,
                cache.len() <= capacity,
                "{} entries in a capacity-{capacity} cache",
                cache.len()
            );
        }
        prop_assert!(
            g,
            cache.stats().insertions >= cache.stats().evictions,
            "more evictions than insertions"
        );
        prop_assert!(
            g,
            (cache.stats().insertions - cache.stats().evictions) as usize == cache.len(),
            "counters don't balance: {} - {} != {}",
            cache.stats().insertions,
            cache.stats().evictions,
            cache.len()
        );
        true
    });
}
