//! Cluster-layer regression tests (ISSUE 2):
//!
//! * **zero bias** — an N=1 cluster at reference speed reproduces
//!   `simulate_dynamic` bit-for-bit, whatever the routing policy: the
//!   cluster layer adds accounting, never behaviour;
//! * **routing dominance** — on a heterogeneous-GPU fleet under load,
//!   quality-aware routing achieves fleet mean quality at least as good
//!   as blind round-robin (lower FID is better).

use aigc_edge::bandwidth::EqualAllocator;
use aigc_edge::config::{ArrivalProcessKind, ArrivalSettings, ExperimentConfig};
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::quality::PowerLawQuality;
use aigc_edge::routing::RouterKind;
use aigc_edge::scheduler::Stacking;
use aigc_edge::sim::{
    server_speeds, simulate_cluster, simulate_dynamic, ClusterConfig, ClusterReport, DynamicConfig,
};
use aigc_edge::trace::ArrivalTrace;

fn trace(rate: f64, horizon: f64, seed: u64) -> ArrivalTrace {
    let cfg = ExperimentConfig::paper();
    let arrival = ArrivalSettings {
        process: ArrivalProcessKind::Poisson,
        rate_hz: rate,
        burst_rate_hz: rate,
        period_s: 60.0,
        duty: 0.5,
        horizon_s: horizon,
        max_requests: 0,
        prompt_universe: 1,
        zipf_s: 1.0,
        models: 1,
    };
    ArrivalTrace::generate(&cfg.scenario, &arrival, seed)
}

fn run_cluster(trace: &ArrivalTrace, cfg: &ClusterConfig) -> ClusterReport {
    simulate_cluster(
        trace,
        &Stacking::default(),
        &EqualAllocator,
        &BatchDelayModel::paper(),
        &PowerLawQuality::paper(),
        cfg,
    )
}

#[test]
fn single_server_cluster_is_bit_identical_to_simulate_dynamic() {
    let t = trace(4.0, 90.0, 7);
    let dyn_cfg = DynamicConfig::default();
    let reference = simulate_dynamic(
        &t,
        &Stacking::default(),
        &EqualAllocator,
        &BatchDelayModel::paper(),
        &PowerLawQuality::paper(),
        &dyn_cfg,
    );
    for router in RouterKind::all() {
        let cluster_cfg = ClusterConfig::homogeneous(1, router, dyn_cfg);
        assert_eq!(cluster_cfg.speeds, vec![1.0], "speed must be exactly 1.0");
        let cluster = run_cluster(&t, &cluster_cfg);

        assert_eq!(cluster.outcomes.len(), reference.outcomes.len(), "{}", router.name());
        for (c, r) in cluster.outcomes.iter().zip(&reference.outcomes) {
            assert_eq!(c.id, r.id);
            assert_eq!(c.disposition, r.disposition, "{}: request {}", router.name(), r.id);
            assert_eq!(c.steps, r.steps);
            assert_eq!(c.deferrals, r.deferrals);
            assert_eq!(c.epoch, r.epoch);
            assert_eq!(c.met, r.met);
            assert_eq!(c.quality.to_bits(), r.quality.to_bits(), "request {}", r.id);
            assert_eq!(c.e2e_s.to_bits(), r.e2e_s.to_bits(), "request {}", r.id);
            assert_eq!(c.wait_s.to_bits(), r.wait_s.to_bits(), "request {}", r.id);
            assert_eq!(c.resolved_s.to_bits(), r.resolved_s.to_bits(), "request {}", r.id);
        }
        assert_eq!(cluster.horizon_s.to_bits(), reference.horizon_s.to_bits());
        // epoch traces agree too
        let server = &cluster.servers[0].report;
        assert_eq!(server.epochs.len(), reference.epochs.len());
        for (c, r) in server.epochs.iter().zip(&reference.epochs) {
            assert_eq!(c.t_solve_s.to_bits(), r.t_solve_s.to_bits());
            assert_eq!(c.queue_depth, r.queue_depth);
            assert_eq!(c.served, r.served);
            assert_eq!(c.dropped, r.dropped);
            assert_eq!(c.makespan_s.to_bits(), r.makespan_s.to_bits());
        }
    }
}

#[test]
fn quality_aware_routing_dominates_round_robin_on_heterogeneous_fleet() {
    // Speeds [0.4, 1.0, 1.6]: round-robin blindly hands the 0.4× GPU a
    // third of the traffic; at λ = 6 Hz that share crawls (about one
    // denoising step per request inside the plan horizon) while the
    // 1.6× server idles below capacity. Quality-aware dispatch predicts
    // the step marginal per server and shifts load accordingly.
    let t = trace(6.0, 80.0, 11);
    let speeds = server_speeds(3, 0.4, 1.6);
    let dynamic = DynamicConfig::default();
    let rr = run_cluster(
        &t,
        &ClusterConfig { speeds: speeds.clone(), router: RouterKind::RoundRobin, dynamic },
    );
    let qa = run_cluster(
        &t,
        &ClusterConfig { speeds, router: RouterKind::QualityAware, dynamic },
    );
    assert!(
        qa.mean_quality() <= rr.mean_quality() + 1e-6,
        "quality-aware fleet FID {:.2} must not lose to round-robin {:.2}",
        qa.mean_quality(),
        rr.mean_quality()
    );
    // and it must do so by actually shifting traffic off the slow GPU
    assert!(
        qa.servers[0].assigned() < rr.servers[0].assigned(),
        "quality-aware sent {} requests to the 0.4x server vs round-robin's {}",
        qa.servers[0].assigned(),
        rr.servers[0].assigned()
    );
}

#[test]
fn dominance_holds_across_seeds() {
    // The λ = 6 Hz heterogeneous dominance above is not a lucky seed:
    // repeat over several seeded traces.
    let speeds = server_speeds(3, 0.4, 1.6);
    let dynamic = DynamicConfig::default();
    for seed in [1, 2, 3] {
        let t = trace(6.0, 40.0, seed);
        let rr = run_cluster(
            &t,
            &ClusterConfig { speeds: speeds.clone(), router: RouterKind::RoundRobin, dynamic },
        );
        let qa = run_cluster(
            &t,
            &ClusterConfig { speeds: speeds.clone(), router: RouterKind::QualityAware, dynamic },
        );
        assert!(
            qa.mean_quality() <= rr.mean_quality() + 1e-6,
            "seed {seed}: quality-aware {:.2} vs round-robin {:.2}",
            qa.mean_quality(),
            rr.mean_quality()
        );
    }
}
