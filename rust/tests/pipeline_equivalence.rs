//! Equivalence guard for the pipelined epoch lifecycle (ISSUE 4): with
//! `solve_latency_s = 0`, the refactored engines must reproduce the
//! pre-pipeline semantics **bit-for-bit** on the seed-7 stream — in
//! both lifecycle modes, for every virtual-view router, on N = 1 and
//! heterogeneous fleets, with faults off and on.
//!
//! Three identities are pinned:
//! * pipelined ≡ synchronous inside the event engine at zero latency
//!   (the lifecycle refactor moves no batch), including under fault
//!   scripts and every migration policy;
//! * the zero-fault event engine ≡ `simulate_cluster` ≡ (at N = 1)
//!   `simulate_dynamic`, per request and per epoch record — and not
//!   just at zero latency: the two engines share `SolveTiming`, so the
//!   mirror holds at every (latency, mode) pair;
//! * the live-state router is mode-invariant at zero latency too.

use aigc_edge::bandwidth::EqualAllocator;
use aigc_edge::config::{ArrivalProcessKind, ArrivalSettings, ExperimentConfig};
use aigc_edge::coordinator::SolveMode;
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::faults::{FaultScript, MigrationPolicyKind, NO_FAULTS};
use aigc_edge::quality::PowerLawQuality;
use aigc_edge::routing::RouterKind;
use aigc_edge::scheduler::Stacking;
use aigc_edge::sim::{
    server_speeds, simulate_cluster, simulate_dynamic, simulate_event_cluster, ClusterConfig,
    DynamicConfig, EpochRecord, EventClusterConfig, EventReport, RequestOutcome,
};
use aigc_edge::trace::ArrivalTrace;

fn seed7_trace(rate: f64, horizon: f64) -> ArrivalTrace {
    let cfg = ExperimentConfig::paper();
    let arrival = ArrivalSettings {
        process: ArrivalProcessKind::Poisson,
        rate_hz: rate,
        burst_rate_hz: rate,
        period_s: 60.0,
        duty: 0.5,
        horizon_s: horizon,
        max_requests: 0,
        prompt_universe: 1,
        zipf_s: 1.0,
        models: 1,
    };
    ArrivalTrace::generate(&cfg.scenario, &arrival, 7)
}

fn run_event(trace: &ArrivalTrace, cfg: &EventClusterConfig) -> EventReport {
    simulate_event_cluster(
        trace,
        &Stacking::default(),
        &EqualAllocator,
        &BatchDelayModel::paper(),
        &PowerLawQuality::paper(),
        cfg,
    )
}

fn assert_outcomes_identical(tag: &str, a: &[RequestOutcome], b: &[RequestOutcome]) {
    assert_eq!(a.len(), b.len(), "{tag}: outcome count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.id, y.id, "{tag}");
        assert_eq!(x.disposition, y.disposition, "{tag} request {}", x.id);
        assert_eq!(x.steps, y.steps, "{tag} request {}", x.id);
        assert_eq!(x.deferrals, y.deferrals, "{tag} request {}", x.id);
        assert_eq!(x.epoch, y.epoch, "{tag} request {}", x.id);
        assert_eq!(x.met, y.met, "{tag} request {}", x.id);
        assert_eq!(x.quality.to_bits(), y.quality.to_bits(), "{tag} request {}", x.id);
        assert_eq!(x.e2e_s.to_bits(), y.e2e_s.to_bits(), "{tag} request {}", x.id);
        assert_eq!(x.wait_s.to_bits(), y.wait_s.to_bits(), "{tag} request {}", x.id);
        assert_eq!(x.resolved_s.to_bits(), y.resolved_s.to_bits(), "{tag} request {}", x.id);
    }
}

fn assert_epochs_identical(tag: &str, a: &[EpochRecord], b: &[EpochRecord]) {
    assert_eq!(a.len(), b.len(), "{tag}: epoch count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.index, y.index, "{tag}");
        assert_eq!(x.t_solve_s.to_bits(), y.t_solve_s.to_bits(), "{tag} epoch {}", x.index);
        assert_eq!(x.queue_depth, y.queue_depth, "{tag} epoch {}", x.index);
        assert_eq!(x.admitted, y.admitted, "{tag} epoch {}", x.index);
        assert_eq!(x.served, y.served, "{tag} epoch {}", x.index);
        assert_eq!(x.deferred, y.deferred, "{tag} epoch {}", x.index);
        assert_eq!(x.dropped, y.dropped, "{tag} epoch {}", x.index);
        assert_eq!(x.makespan_s.to_bits(), y.makespan_s.to_bits(), "{tag} epoch {}", x.index);
        assert_eq!(
            x.solve_hidden_s.to_bits(),
            y.solve_hidden_s.to_bits(),
            "{tag} epoch {}",
            x.index
        );
        assert_eq!(
            x.solve_overlap_w.to_bits(),
            y.solve_overlap_w.to_bits(),
            "{tag} epoch {}",
            x.index
        );
    }
}

fn event_cfg<'a>(
    speeds: &'a [f64],
    router: RouterKind,
    dynamic: DynamicConfig,
    faults: &'a FaultScript,
    migration: MigrationPolicyKind,
) -> EventClusterConfig<'a> {
    EventClusterConfig { speeds, router, dynamic, faults, migration, resume_transfer_s: 0.0 }
}

fn with_mode(mode: SolveMode, latency: f64) -> DynamicConfig {
    DynamicConfig { solve_mode: mode, solve_latency_s: latency, ..DynamicConfig::default() }
}

/// Zero solve latency, zero faults: pipelined ≡ synchronous ≡ the
/// sequential cluster, for every virtual-view router, on N = 1 and a
/// heterogeneous fleet — the ISSUE 4 bit-identity criterion.
#[test]
fn seed7_zero_latency_all_routers_all_fleets() {
    let trace = seed7_trace(6.0, 60.0);
    for speeds in [vec![1.0], server_speeds(3, 0.5, 1.5)] {
        for router in RouterKind::all() {
            let tag = format!("{} x{}", router.name(), speeds.len());
            let pipelined = run_event(
                &trace,
                &event_cfg(
                    &speeds,
                    router,
                    with_mode(SolveMode::Pipelined, 0.0),
                    &NO_FAULTS,
                    MigrationPolicyKind::None,
                ),
            );
            let sync = run_event(
                &trace,
                &event_cfg(
                    &speeds,
                    router,
                    with_mode(SolveMode::Synchronous, 0.0),
                    &NO_FAULTS,
                    MigrationPolicyKind::None,
                ),
            );
            assert_eq!(pipelined.assignment, sync.assignment, "{tag}");
            assert_outcomes_identical(&tag, &pipelined.outcomes, &sync.outcomes);
            assert_eq!(pipelined.horizon_s.to_bits(), sync.horizon_s.to_bits(), "{tag}");

            // …and both match the pre-pipeline sequential cluster.
            let cluster = ClusterConfig {
                speeds: speeds.clone(),
                router,
                dynamic: DynamicConfig::default(),
            };
            let seq = simulate_cluster(
                &trace,
                &Stacking::default(),
                &EqualAllocator,
                &BatchDelayModel::paper(),
                &PowerLawQuality::paper(),
                &cluster,
            );
            assert_eq!(pipelined.assignment, seq.assignment, "{tag}");
            assert_outcomes_identical(&tag, &pipelined.outcomes, &seq.outcomes);
            assert_eq!(pipelined.horizon_s.to_bits(), seq.horizon_s.to_bits(), "{tag}");
            for (srv_ev, srv_seq) in pipelined.servers.iter().zip(&seq.servers) {
                let tag = format!("{tag} server {}", srv_ev.server);
                assert_epochs_identical(&tag, &srv_ev.epochs, &srv_seq.report.epochs);
            }
        }
    }
}

/// N = 1 at zero latency: the pipelined engine is bit-identical to
/// `simulate_dynamic` itself, including epoch records.
#[test]
fn seed7_single_server_matches_simulate_dynamic() {
    let trace = seed7_trace(6.0, 60.0);
    for mode in SolveMode::all() {
        let dynamic = with_mode(mode, 0.0);
        let ev = run_event(
            &trace,
            &event_cfg(
                &[1.0],
                RouterKind::RoundRobin,
                dynamic,
                &NO_FAULTS,
                MigrationPolicyKind::None,
            ),
        );
        let dy = simulate_dynamic(
            &trace,
            &Stacking::default(),
            &EqualAllocator,
            &BatchDelayModel::paper(),
            &PowerLawQuality::paper(),
            &dynamic,
        );
        let tag = format!("N=1 {}", mode.name());
        assert_outcomes_identical(&tag, &ev.outcomes, &dy.outcomes);
        assert_epochs_identical(&tag, &ev.servers[0].epochs, &dy.epochs);
        assert_eq!(ev.horizon_s.to_bits(), dy.horizon_s.to_bits(), "{tag}");
    }
}

/// Zero latency under failure injection: the lifecycle refactor must
/// not move a single fault, migration or resolution in either mode —
/// across scheduled and random scripts and every migration policy.
#[test]
fn seed7_zero_latency_with_faults_mode_invariant() {
    let trace = seed7_trace(5.0, 60.0);
    let scripts = [
        FaultScript::random(3, 60.0, 25.0, 8.0, 11),
        FaultScript::parse_spec("1:10:25,0:40:55").map(|d| FaultScript::scheduled(d).unwrap())
            .unwrap(),
    ];
    for script in scripts {
        for policy in MigrationPolicyKind::all() {
            let tag = format!("faults {}", policy.name());
            let pipelined = run_event(
                &trace,
                &event_cfg(
                    &server_speeds(3, 0.5, 1.5),
                    RouterKind::JoinShortestQueue,
                    with_mode(SolveMode::Pipelined, 0.0),
                    &script,
                    policy,
                ),
            );
            let sync = run_event(
                &trace,
                &event_cfg(
                    &server_speeds(3, 0.5, 1.5),
                    RouterKind::JoinShortestQueue,
                    with_mode(SolveMode::Synchronous, 0.0),
                    &script,
                    policy,
                ),
            );
            assert_eq!(pipelined.assignment, sync.assignment, "{tag}");
            assert_outcomes_identical(&tag, &pipelined.outcomes, &sync.outcomes);
            assert_eq!(pipelined.migrations.len(), sync.migrations.len(), "{tag}");
            assert_eq!(pipelined.fault_log.len(), sync.fault_log.len(), "{tag}");
            assert_eq!(pipelined.horizon_s.to_bits(), sync.horizon_s.to_bits(), "{tag}");
        }
    }
}

/// The live-state router is mode-invariant at zero latency too: both
/// lifecycles publish identical live views at identical instants.
#[test]
fn seed7_zero_latency_live_router_mode_invariant() {
    let trace = seed7_trace(6.0, 60.0);
    let pipelined = run_event(
        &trace,
        &event_cfg(
            &server_speeds(3, 0.5, 1.5),
            RouterKind::LiveState,
            with_mode(SolveMode::Pipelined, 0.0),
            &NO_FAULTS,
            MigrationPolicyKind::None,
        ),
    );
    let sync = run_event(
        &trace,
        &event_cfg(
            &server_speeds(3, 0.5, 1.5),
            RouterKind::LiveState,
            with_mode(SolveMode::Synchronous, 0.0),
            &NO_FAULTS,
            MigrationPolicyKind::None,
        ),
    );
    assert_eq!(pipelined.assignment, sync.assignment, "live");
    assert_outcomes_identical("live", &pipelined.outcomes, &sync.outcomes);
    assert_eq!(pipelined.horizon_s.to_bits(), sync.horizon_s.to_bits());
}

/// The mirror contract extends past zero latency: the event engine and
/// the sequential cluster share `SolveTiming`, so the zero-fault case
/// stays bit-identical at every (latency, mode) pair — and so does
/// `simulate_dynamic` at N = 1.
#[test]
fn seed7_nonzero_latency_engines_stay_mirrored() {
    let trace = seed7_trace(6.0, 50.0);
    for mode in SolveMode::all() {
        for latency in [0.1, 0.35] {
            let dynamic = with_mode(mode, latency);
            for router in [RouterKind::JoinShortestQueue, RouterKind::QualityAware] {
                let tag = format!("{} {} L={latency}", router.name(), mode.name());
                let ev = run_event(
                    &trace,
                    &event_cfg(
                        &server_speeds(3, 0.5, 1.5),
                        router,
                        dynamic,
                        &NO_FAULTS,
                        MigrationPolicyKind::None,
                    ),
                );
                let cluster =
                    ClusterConfig { speeds: server_speeds(3, 0.5, 1.5), router, dynamic };
                let seq = simulate_cluster(
                    &trace,
                    &Stacking::default(),
                    &EqualAllocator,
                    &BatchDelayModel::paper(),
                    &PowerLawQuality::paper(),
                    &cluster,
                );
                assert_eq!(ev.assignment, seq.assignment, "{tag}");
                assert_outcomes_identical(&tag, &ev.outcomes, &seq.outcomes);
                assert_eq!(ev.horizon_s.to_bits(), seq.horizon_s.to_bits(), "{tag}");
                for (srv_ev, srv_seq) in ev.servers.iter().zip(&seq.servers) {
                    let tag = format!("{tag} server {}", srv_ev.server);
                    assert_epochs_identical(&tag, &srv_ev.epochs, &srv_seq.report.epochs);
                }
            }
        }
    }
}
