//! Equivalence guard for the shared-clock event engine (ISSUE 3): with
//! an empty `FaultScript` and `MigrationPolicy::None`, `sim::event`
//! must reproduce `simulate_cluster` **bit-for-bit** on the seed-7
//! stream — the same regression style as PR 2's N=1 dominance test.
//!
//! The comparison is exhaustive: per-request outcomes (disposition,
//! steps, bit-level quality/delay/resolution instants), the dispatch
//! assignment, per-server epoch traces, and the fleet aggregates.

use aigc_edge::bandwidth::EqualAllocator;
use aigc_edge::config::{ArrivalProcessKind, ArrivalSettings, ExperimentConfig};
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::quality::PowerLawQuality;
use aigc_edge::routing::RouterKind;
use aigc_edge::scheduler::Stacking;
use aigc_edge::sim::{
    server_speeds, simulate_cluster, simulate_event_cluster, ClusterConfig, ClusterReport,
    DynamicConfig, EpochRecord, EventClusterConfig, EventReport,
};
use aigc_edge::trace::ArrivalTrace;

fn seed7_trace(rate: f64, horizon: f64) -> ArrivalTrace {
    let cfg = ExperimentConfig::paper();
    let arrival = ArrivalSettings {
        process: ArrivalProcessKind::Poisson,
        rate_hz: rate,
        burst_rate_hz: rate,
        period_s: 60.0,
        duty: 0.5,
        horizon_s: horizon,
        max_requests: 0,
        prompt_universe: 1,
        zipf_s: 1.0,
        models: 1,
    };
    ArrivalTrace::generate(&cfg.scenario, &arrival, 7)
}

fn run_both(trace: &ArrivalTrace, cluster: &ClusterConfig) -> (ClusterReport, EventReport) {
    let scheduler = Stacking::default();
    let delay = BatchDelayModel::paper();
    let quality = PowerLawQuality::paper();
    let seq = simulate_cluster(trace, &scheduler, &EqualAllocator, &delay, &quality, cluster);
    let ev = simulate_event_cluster(
        trace,
        &scheduler,
        &EqualAllocator,
        &delay,
        &quality,
        &EventClusterConfig::fault_free(cluster),
    );
    (seq, ev)
}

fn assert_epochs_identical(tag: &str, seq: &[EpochRecord], ev: &[EpochRecord]) {
    assert_eq!(seq.len(), ev.len(), "{tag}: epoch count");
    for (a, b) in seq.iter().zip(ev) {
        assert_eq!(a.index, b.index, "{tag}");
        assert_eq!(a.t_solve_s.to_bits(), b.t_solve_s.to_bits(), "{tag} epoch {}", a.index);
        assert_eq!(a.queue_depth, b.queue_depth, "{tag} epoch {}", a.index);
        assert_eq!(a.admitted, b.admitted, "{tag} epoch {}", a.index);
        assert_eq!(a.served, b.served, "{tag} epoch {}", a.index);
        assert_eq!(a.deferred, b.deferred, "{tag} epoch {}", a.index);
        assert_eq!(a.dropped, b.dropped, "{tag} epoch {}", a.index);
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits(), "{tag} epoch {}", a.index);
        assert_eq!(a.arrival_rate_hz.to_bits(), b.arrival_rate_hz.to_bits(), "{tag}");
        assert_eq!(a.mean_quality_w.to_bits(), b.mean_quality_w.to_bits(), "{tag}");
        assert_eq!(a.outage_rate_w.to_bits(), b.outage_rate_w.to_bits(), "{tag}");
        assert_eq!(a.p50_e2e_w.to_bits(), b.p50_e2e_w.to_bits(), "{tag}");
        assert_eq!(a.p95_e2e_w.to_bits(), b.p95_e2e_w.to_bits(), "{tag}");
        assert_eq!(a.p99_e2e_w.to_bits(), b.p99_e2e_w.to_bits(), "{tag}");
    }
}

fn assert_reports_identical(tag: &str, seq: &ClusterReport, ev: &EventReport) {
    assert_eq!(ev.assignment, seq.assignment, "{tag}: dispatch assignment");
    assert_eq!(ev.outcomes.len(), seq.outcomes.len(), "{tag}");
    for (a, b) in ev.outcomes.iter().zip(&seq.outcomes) {
        assert_eq!(a.id, b.id, "{tag}");
        assert_eq!(a.disposition, b.disposition, "{tag} request {}", a.id);
        assert_eq!(a.steps, b.steps, "{tag} request {}", a.id);
        assert_eq!(a.deferrals, b.deferrals, "{tag} request {}", a.id);
        assert_eq!(a.epoch, b.epoch, "{tag} request {}", a.id);
        assert_eq!(a.met, b.met, "{tag} request {}", a.id);
        assert_eq!(a.quality.to_bits(), b.quality.to_bits(), "{tag} request {}", a.id);
        assert_eq!(a.e2e_s.to_bits(), b.e2e_s.to_bits(), "{tag} request {}", a.id);
        assert_eq!(a.wait_s.to_bits(), b.wait_s.to_bits(), "{tag} request {}", a.id);
        assert_eq!(a.resolved_s.to_bits(), b.resolved_s.to_bits(), "{tag} request {}", a.id);
    }
    assert_eq!(ev.horizon_s.to_bits(), seq.horizon_s.to_bits(), "{tag}: horizon");
    // fleet aggregates bit-for-bit (the ISSUE acceptance criterion)
    let (s, e) = (seq.fleet_stats(), ev.fleet_stats());
    assert_eq!(s.count, e.count, "{tag}");
    assert_eq!(s.served, e.served, "{tag}");
    assert_eq!(s.mean_quality.to_bits(), e.mean_quality.to_bits(), "{tag}");
    assert_eq!(s.outage_rate.to_bits(), e.outage_rate.to_bits(), "{tag}");
    assert_eq!(s.p50_e2e_s.to_bits(), e.p50_e2e_s.to_bits(), "{tag}");
    assert_eq!(s.p95_e2e_s.to_bits(), e.p95_e2e_s.to_bits(), "{tag}");
    assert_eq!(s.p99_e2e_s.to_bits(), e.p99_e2e_s.to_bits(), "{tag}");
    assert_eq!(s.mean_wait_s.to_bits(), e.mean_wait_s.to_bits(), "{tag}");
    // per-server epoch traces
    for (srv_seq, srv_ev) in seq.servers.iter().zip(&ev.servers) {
        assert_eq!(srv_seq.assigned_ids, srv_ev.assigned_ids, "{tag} server {}", srv_seq.server);
        let tag = format!("{tag} server {}", srv_seq.server);
        assert_epochs_identical(&tag, &srv_seq.report.epochs, &srv_ev.epochs);
    }
    // the zero-fault engine must not invent migrations or faults
    assert!(ev.migrations.is_empty(), "{tag}");
    assert!(ev.fault_log.is_empty(), "{tag}");
    assert_eq!(ev.lost_to_failure(), 0, "{tag}");
}

#[test]
fn seed7_heterogeneous_fleet_every_router() {
    let trace = seed7_trace(6.0, 60.0);
    for router in RouterKind::all() {
        let cluster = ClusterConfig {
            speeds: server_speeds(3, 0.5, 1.5),
            router,
            dynamic: DynamicConfig::default(),
        };
        let (seq, ev) = run_both(&trace, &cluster);
        assert_reports_identical(router.name(), &seq, &ev);
    }
}

#[test]
fn seed7_single_server_and_overload() {
    // N = 1 collapses both engines onto simulate_dynamic; overload
    // exercises admission drops, deferrals and backlogged epochs.
    for (n, rate) in [(1usize, 4.0), (2, 12.0)] {
        let trace = seed7_trace(rate, 45.0);
        let cluster = ClusterConfig::homogeneous(
            n,
            RouterKind::RoundRobin,
            DynamicConfig::default(),
        );
        let (seq, ev) = run_both(&trace, &cluster);
        assert_reports_identical(&format!("n={n} rate={rate}"), &seq, &ev);
    }
}

#[test]
fn seed7_small_epochs_force_carry_over_paths() {
    // Tiny epochs + small batches exercise the backlog/carry-over
    // epoch-opening rules, the trickiest part of the replay.
    let trace = seed7_trace(10.0, 40.0);
    let dynamic = DynamicConfig {
        epoch: aigc_edge::coordinator::EpochPolicy::new(0.25, 4),
        ..DynamicConfig::default()
    };
    let cluster = ClusterConfig {
        speeds: server_speeds(2, 0.6, 1.0),
        router: RouterKind::QualityAware,
        dynamic,
    };
    let (seq, ev) = run_both(&trace, &cluster);
    assert_reports_identical("small-epochs", &seq, &ev);
}

#[test]
fn adaptive_horizon_preserves_equivalence() {
    // The adaptive planning horizon is computed identically in both
    // engines, so equivalence must survive turning it on.
    let trace = seed7_trace(8.0, 40.0);
    let dynamic = DynamicConfig { plan_horizon_adaptive: true, ..DynamicConfig::default() };
    let cluster = ClusterConfig {
        speeds: server_speeds(3, 0.5, 2.0),
        router: RouterKind::JoinShortestQueue,
        dynamic,
    };
    let (seq, ev) = run_both(&trace, &cluster);
    assert_reports_identical("adaptive-horizon", &seq, &ev);
}
