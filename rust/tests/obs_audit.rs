//! Span-audit correctness harness (the flight recorder's property
//! suite): every capture either engine can produce — across random
//! traces × routers × fault scripts × migration policies — must pass
//! the `obs::audit` lifecycle DFA with zero violations and conserve
//! the request count. CI runs this under `cargo test`; a single
//! lifecycle violation anywhere in the sweep fails the job.
//!
//! The audit is only a gate if it can actually fail, so the last test
//! corrupts a clean capture in targeted ways (dropped birth,
//! duplicated terminal, wrong census) and asserts each is flagged.

use aigc_edge::bandwidth::EqualAllocator;
use aigc_edge::config::ExperimentConfig;
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::faults::{DownInterval, FaultScript, MigrationPolicyKind};
use aigc_edge::obs::{audit, EventKind, Recorder, TraceEvent};
use aigc_edge::quality::PowerLawQuality;
use aigc_edge::routing::RouterKind;
use aigc_edge::scheduler::Stacking;
use aigc_edge::sim::{
    server_speeds, simulate_cluster_traced, simulate_event_cluster_traced, ClusterConfig,
    DynamicConfig, EventClusterConfig,
};
use aigc_edge::trace::ArrivalTrace;

fn trace(rate_hz: f64, horizon_s: f64, seed: u64) -> ArrivalTrace {
    let mut cfg = ExperimentConfig::paper();
    cfg.arrival.rate_hz = rate_hz;
    cfg.arrival.horizon_s = horizon_s;
    ArrivalTrace::generate(&cfg.scenario, &cfg.arrival, seed)
}

fn dyn_cfg() -> DynamicConfig {
    (&ExperimentConfig::paper().dynamic).into()
}

/// The three fault regimes the `faults` CLI exposes: none, a scheduled
/// pair of mid-trace outages, and a seeded random MTBF/MTTR script.
fn scripts(servers: usize, horizon_s: f64, seed: u64) -> Vec<FaultScript> {
    let downs = vec![
        DownInterval::new(0, horizon_s * 0.2, horizon_s * 0.35).unwrap(),
        DownInterval::new(servers - 1, horizon_s * 0.5, horizon_s * 0.65).unwrap(),
    ];
    let scheduled = FaultScript::scheduled(downs).unwrap();
    let random = FaultScript::random(servers, horizon_s, horizon_s / 3.0, horizon_s / 8.0, seed);
    vec![FaultScript::empty(), scheduled, random]
}

fn assert_clean(events: &[TraceEvent], n: usize, ctx: &str) {
    let report = audit::audit_expecting(events, n);
    assert!(report.is_clean(), "{ctx}:\n{}", report.render());
    assert!(events.len() >= 2 * n, "{ctx}: capture too sparse ({} events)", events.len());
}

#[test]
fn event_engine_captures_audit_clean_across_the_grid() {
    let scheduler = Stacking::default();
    let delay = BatchDelayModel::paper();
    let quality = PowerLawQuality::paper();
    let servers = 3;
    let horizon_s = 40.0;
    let speeds = server_speeds(servers, 0.5, 1.5);
    for seed in [1u64, 2] {
        let t = trace(5.0, horizon_s, seed);
        for router in RouterKind::with_live() {
            for (si, script) in scripts(servers, horizon_s, seed).iter().enumerate() {
                for policy in MigrationPolicyKind::all() {
                    let cfg = EventClusterConfig {
                        speeds: &speeds,
                        router,
                        dynamic: dyn_cfg(),
                        faults: script,
                        migration: policy,
                        resume_transfer_s: 0.25,
                    };
                    let mut rec = Recorder::new();
                    simulate_event_cluster_traced(
                        &t,
                        &scheduler,
                        &EqualAllocator,
                        &delay,
                        &quality,
                        &cfg,
                        &mut rec,
                    );
                    let ctx = format!(
                        "seed {seed} router {} script {si} policy {}",
                        router.name(),
                        policy.name(),
                    );
                    assert_clean(&rec.events, t.len(), &ctx);
                }
            }
        }
    }
}

#[test]
fn sequential_cluster_captures_audit_clean_for_every_virtual_router() {
    let scheduler = Stacking::default();
    let delay = BatchDelayModel::paper();
    let quality = PowerLawQuality::paper();
    for seed in [1u64, 2] {
        let t = trace(5.0, 40.0, seed);
        for router in RouterKind::all() {
            let cfg = ClusterConfig {
                speeds: server_speeds(3, 0.5, 1.5),
                router,
                dynamic: dyn_cfg(),
            };
            let mut rec = Recorder::new();
            simulate_cluster_traced(
                &t,
                &scheduler,
                &EqualAllocator,
                &delay,
                &quality,
                &cfg,
                &mut rec,
            );
            let ctx = format!("seed {seed} router {}", router.name());
            assert_clean(&rec.events, t.len(), &ctx);
            // The merge loop synthesizes exactly one Routed per arrival.
            let routed = rec.events.iter().filter(|e| matches!(e.kind, EventKind::Routed { .. }));
            assert_eq!(routed.count(), t.len(), "{ctx}: routing events");
        }
    }
}

#[test]
fn audit_flags_corrupted_captures() {
    let t = trace(5.0, 30.0, 3);
    let speeds = server_speeds(3, 0.5, 1.5);
    let faults = FaultScript::random(3, 30.0, 10.0, 4.0, 9);
    let cfg = EventClusterConfig {
        speeds: &speeds,
        router: RouterKind::JoinShortestQueue,
        dynamic: dyn_cfg(),
        faults: &faults,
        migration: MigrationPolicyKind::Checkpoint,
        resume_transfer_s: 0.25,
    };
    let mut rec = Recorder::new();
    simulate_event_cluster_traced(
        &t,
        &Stacking::default(),
        &EqualAllocator,
        &BatchDelayModel::paper(),
        &PowerLawQuality::paper(),
        &cfg,
        &mut rec,
    );
    let events = rec.events;
    assert!(audit::audit_expecting(&events, t.len()).is_clean());

    // A request whose birth never made it into the stream.
    let orphaned: Vec<TraceEvent> = events
        .iter()
        .copied()
        .filter(|e| !(e.kind == EventKind::Arrived && e.request == 0))
        .collect();
    assert!(!audit::audit_expecting(&orphaned, t.len()).is_clean(), "dropped birth not flagged");

    // A request resolved twice (double-counted by a buggy engine).
    let dup = events.iter().copied().find(|e| e.kind.is_terminal()).expect("a terminal event");
    let mut doubled = events.clone();
    doubled.push(dup);
    assert!(!audit::audit(&doubled).is_clean(), "duplicated terminal not flagged");

    // A census mismatch: the trace claims more requests than captured.
    assert!(!audit::audit_expecting(&events, t.len() + 1).is_clean(), "census gap not flagged");
}
