//! Golden regression tests: seeded figure rows pinned to JSON fixtures
//! under `tests/fixtures/`, so scheduler/allocator refactors cannot
//! silently shift the paper's results.
//!
//! Two fixture classes:
//!
//! * **Committed, machine-independent** (`workload_seed7.json`,
//!   `models_paper.json`): produced by the independent Python port in
//!   `tools/gen_golden_fixtures.py` (exact u64/IEEE arithmetic, PCG
//!   port verified against the canonical reference vector). These must
//!   exist and match tightly.
//! * **Bless-on-first-run** (`golden_fig2*.json`, `golden_fig3.json`):
//!   full-pipeline rows (PSO ∘ STACKING, dynamic sweep). On a machine
//!   where the fixture is absent the test writes it and passes with a
//!   notice — commit the generated file to pin the numbers. Set
//!   `GOLDEN_BLESS=1` to intentionally regenerate after a behaviour
//!   change. Comparison tolerance absorbs libm (`powf`) differences
//!   across platforms.

use std::collections::BTreeMap;
use std::path::PathBuf;

use aigc_edge::config::ExperimentConfig;
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::quality::{PowerLawQuality, QualityModel};
use aigc_edge::trace::generate;
use aigc_edge::util::json::{parse, Json};

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

fn load_fixture(name: &str) -> Json {
    let path = fixture_path(name);
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("committed fixture {path:?} missing: {e}"));
    parse(&text).unwrap_or_else(|e| panic!("fixture {path:?} unparseable: {e}"))
}

// ---------------------------------------------------------------------------
// committed fixtures
// ---------------------------------------------------------------------------

#[test]
fn golden_workload_seed7_matches_python_port() {
    let fixture = load_fixture("workload_seed7.json");
    let cfg = ExperimentConfig::paper();
    let workload = generate(&cfg.scenario, 7);
    let devices = fixture.get("devices").and_then(Json::as_arr).expect("devices array");
    assert_eq!(devices.len(), workload.k(), "device count");
    for (expect, got) in devices.iter().zip(&workload.devices) {
        let id = expect.get("id").and_then(Json::as_f64).unwrap() as usize;
        let deadline = expect.get("deadline").and_then(Json::as_f64).unwrap();
        let eta = expect.get("eta").and_then(Json::as_f64).unwrap();
        assert_eq!(got.id, id);
        // identical op-for-op IEEE arithmetic: equality up to printing
        assert!(
            (got.deadline - deadline).abs() < 1e-12,
            "device {id}: deadline {} != {deadline}",
            got.deadline
        );
        assert!(
            (got.link.spectral_efficiency - eta).abs() < 1e-12,
            "device {id}: eta {} != {eta}",
            got.link.spectral_efficiency
        );
    }
}

#[test]
fn golden_paper_models_match_python_port() {
    let fixture = load_fixture("models_paper.json");
    let delay = BatchDelayModel::paper();
    let quality = PowerLawQuality::paper();
    let Some(Json::Obj(gs)) = fixture.get("delay_g").map(Clone::clone) else {
        panic!("delay_g missing")
    };
    for (x, v) in &gs {
        let x: u32 = x.parse().unwrap();
        let expect = v.as_f64().unwrap();
        assert!((delay.g(x) - expect).abs() < 1e-12, "g({x}) = {} != {expect}", delay.g(x));
    }
    let Some(Json::Obj(qs)) = fixture.get("quality").map(Clone::clone) else {
        panic!("quality missing")
    };
    for (t, v) in &qs {
        let t: u32 = t.parse().unwrap();
        let expect = v.as_f64().unwrap();
        let got = quality.quality(t);
        // powf goes through libm: allow an ulp-scale relative slack
        assert!(
            (got - expect).abs() <= 1e-9 * expect.abs().max(1.0),
            "q({t}) = {got} != {expect}"
        );
    }
}

// ---------------------------------------------------------------------------
// bless-on-first-run fixtures (full pipeline)
// ---------------------------------------------------------------------------

/// Compare `rows` against the named fixture, or bless it when absent
/// (or when `GOLDEN_BLESS=1`). Keys must match exactly; values within
/// `abs + rel·|expected|`.
fn check_or_bless(name: &str, rows: &BTreeMap<String, f64>, abs: f64, rel: f64) {
    let path = fixture_path(name);
    let bless = std::env::var("GOLDEN_BLESS").is_ok() || !path.exists();
    if bless {
        let mut out = String::from("{\n");
        let entries: Vec<String> =
            rows.iter().map(|(k, v)| format!("  \"{k}\": {v:?}")).collect();
        out.push_str(&entries.join(",\n"));
        out.push_str("\n}\n");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, out).unwrap();
        eprintln!("golden: blessed {path:?} with {} entries — commit this file", rows.len());
        return;
    }
    let fixture = load_fixture(name);
    let Json::Obj(map) = &fixture else { panic!("{name}: fixture must be an object") };
    let expected_keys: Vec<&String> = map.keys().collect();
    let got_keys: Vec<&String> = rows.keys().collect();
    assert_eq!(expected_keys, got_keys, "{name}: key set drifted");
    for (k, v) in rows {
        let expect = map[k].as_f64().unwrap_or_else(|| panic!("{name}: {k} not a number"));
        let tol = abs + rel * expect.abs();
        assert!(
            (v - expect).abs() <= tol,
            "{name}: {k} = {v} drifted from golden {expect} (tol {tol})"
        );
    }
}

fn quick_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper();
    cfg.pso.particles = 6;
    cfg.pso.iterations = 6;
    cfg.pso.patience = 3;
    cfg
}

#[test]
fn golden_fig2a_rows() {
    let rows = aigc_edge::bench::fig2a(&quick_cfg());
    let mut flat = BTreeMap::new();
    for (id, deadline, gen, tx, e2e, steps) in rows {
        flat.insert(format!("svc{id:02}.deadline"), deadline);
        flat.insert(format!("svc{id:02}.gen"), gen);
        flat.insert(format!("svc{id:02}.tx"), tx);
        flat.insert(format!("svc{id:02}.e2e"), e2e);
        flat.insert(format!("svc{id:02}.steps"), steps as f64);
    }
    check_or_bless("golden_fig2a.json", &flat, 5e-3, 2e-3);
}

#[test]
fn golden_fig2b_rows() {
    let rows = aigc_edge::bench::fig2b(&quick_cfg(), &[5, 20, 35], 1);
    let mut flat = BTreeMap::new();
    for (k, vals) in rows {
        for (i, v) in vals.iter().enumerate() {
            flat.insert(format!("k{k:02}.scheme{i}"), *v);
        }
    }
    check_or_bless("golden_fig2b.json", &flat, 5e-3, 2e-3);
}

#[test]
fn golden_fig2c_rows() {
    let rows = aigc_edge::bench::fig2c(&quick_cfg(), &[3.0, 11.0, 19.0], 1);
    let mut flat = BTreeMap::new();
    for (tau, vals) in rows {
        for (i, v) in vals.iter().enumerate() {
            flat.insert(format!("tau{tau:04.1}.scheme{i}"), *v);
        }
    }
    check_or_bless("golden_fig2c.json", &flat, 5e-3, 2e-3);
}

#[test]
fn golden_fig_cluster_router_sweep() {
    // Reuses the committed seed-7 PCG stream (`workload_seed7.json`
    // pins that generator path) so the fixture stays machine-portable:
    // the trace marks are exact u64/IEEE arithmetic, only the figure
    // aggregates need the libm tolerance.
    let mut cfg = ExperimentConfig::paper();
    cfg.seed = 7;
    cfg.cluster.servers = 3;
    cfg.cluster.speed_min = 0.5;
    cfg.cluster.speed_max = 1.5;
    let rows = aigc_edge::bench::fig_cluster(&cfg, &[1.0, 4.0], 40.0);
    let mut flat = BTreeMap::new();
    for r in rows {
        let tag = format!("lambda{:04.1}.{}", r.lambda_hz, r.router.name());
        flat.insert(format!("{tag}.requests"), r.requests as f64);
        flat.insert(format!("{tag}.served"), r.served as f64);
        flat.insert(format!("{tag}.mean_quality"), r.mean_quality);
        flat.insert(format!("{tag}.outage_rate"), r.outage_rate);
        flat.insert(format!("{tag}.p99_e2e"), r.p99_e2e_s);
        flat.insert(format!("{tag}.max_share"), r.max_share);
    }
    check_or_bless("golden_fig_cluster.json", &flat, 5e-3, 2e-3);
}

#[test]
fn golden_fig_faults_sweep() {
    // Seed-7 stream like the cluster fixture; a modest heterogeneous
    // fleet under one no-fault and one faulted rate, across all three
    // migration policies.
    let mut cfg = ExperimentConfig::paper();
    cfg.seed = 7;
    cfg.cluster.servers = 3;
    cfg.cluster.speed_min = 0.5;
    cfg.cluster.speed_max = 1.5;
    cfg.arrival.rate_hz = 5.0;
    let rows = aigc_edge::bench::fig_faults(&cfg, &[0.0, 2.0], 40.0);
    let mut flat = BTreeMap::new();
    for r in rows {
        let tag = format!("rate{:04.1}.{}", r.fault_rate_per_min, r.policy.name());
        flat.insert(format!("{tag}.requests"), r.requests as f64);
        flat.insert(format!("{tag}.served"), r.served as f64);
        flat.insert(format!("{tag}.lost"), r.lost_to_failure as f64);
        flat.insert(format!("{tag}.migrated"), r.migrated as f64);
        flat.insert(format!("{tag}.failures"), r.failures as f64);
        flat.insert(format!("{tag}.mean_quality"), r.mean_quality);
        flat.insert(format!("{tag}.outage_rate"), r.outage_rate);
        flat.insert(format!("{tag}.p99_e2e"), r.p99_e2e_s);
        flat.insert(format!("{tag}.post_p99"), r.post_failure_p99_s);
        flat.insert(format!("{tag}.drain"), r.mean_time_to_drain_s);
    }
    check_or_bless("golden_fig_faults.json", &flat, 5e-3, 2e-3);
}

#[test]
fn golden_fig_pipeline_sweep() {
    // Seed-7 stream like the cluster fixture; a modest heterogeneous
    // fleet across one zero and one nonzero solve latency, both modes
    // and both fleet views.
    let mut cfg = ExperimentConfig::paper();
    cfg.seed = 7;
    cfg.cluster.servers = 3;
    cfg.cluster.speed_min = 0.5;
    cfg.cluster.speed_max = 1.5;
    cfg.arrival.rate_hz = 3.0;
    cfg.arrival.burst_rate_hz = 10.0;
    let rows = aigc_edge::bench::fig_pipeline(&cfg, &[0.0, 0.25], 40.0);
    let mut flat = BTreeMap::new();
    for r in rows {
        let tag =
            format!("solve{:04.2}.{}.{}", r.solve_latency_s, r.mode.name(), r.router.name());
        flat.insert(format!("{tag}.requests"), r.requests as f64);
        flat.insert(format!("{tag}.served"), r.served as f64);
        flat.insert(format!("{tag}.mean_quality"), r.mean_quality);
        flat.insert(format!("{tag}.outage_rate"), r.outage_rate);
        flat.insert(format!("{tag}.mean_e2e_censored"), r.mean_e2e_censored_s);
        flat.insert(format!("{tag}.p99_e2e_censored"), r.p99_e2e_censored_s);
        flat.insert(format!("{tag}.solve_overlap"), r.solve_overlap);
    }
    check_or_bless("golden_fig_pipeline.json", &flat, 5e-3, 2e-3);
}

#[test]
fn golden_fig3_dynamic_sweep() {
    let rows = aigc_edge::bench::fig3_dynamic(&ExperimentConfig::paper(), &[1.0, 4.0], 40.0);
    let mut flat = BTreeMap::new();
    for r in rows {
        let tag = format!("lambda{:04.1}", r.lambda_hz);
        flat.insert(format!("{tag}.requests"), r.requests as f64);
        flat.insert(format!("{tag}.served"), r.served as f64);
        flat.insert(format!("{tag}.mean_quality"), r.mean_quality);
        flat.insert(format!("{tag}.outage_rate"), r.outage_rate);
        flat.insert(format!("{tag}.p99_e2e"), r.p99_e2e_s);
        flat.insert(format!("{tag}.mean_wait"), r.mean_wait_s);
        flat.insert(format!("{tag}.epochs"), r.epochs as f64);
    }
    check_or_bless("golden_fig3.json", &flat, 5e-3, 2e-3);
}
