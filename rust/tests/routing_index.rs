//! ISSUE 10 forall suite: indexed routing is decision-identical to
//! the O(N) reference scan. Three layers, all deterministic:
//!
//!  * pointwise — random fleets (random speeds, queue histories,
//!    kill/revive churn) probed with random arrivals: `route_indexed`
//!    and `route_resume_indexed` must pick exactly the scan's server,
//!    for every policy and step credit;
//!  * trace — `route_trace` (indexed, incremental) versus
//!    `route_trace_scan` (the executable specification) over marked
//!    random traces: identical assignment vectors;
//!  * engine — `simulate_event_cluster` (indexed dispatch) versus
//!    `simulate_event_cluster_scan` under random fault scripts and
//!    migration policies: bitwise-identical reports, reroutes and
//!    checkpoint resumes included.

use aigc_edge::bandwidth::EqualAllocator;
use aigc_edge::cache::CacheSettings;
use aigc_edge::channel::Link;
use aigc_edge::config::{ArrivalProcessKind, ArrivalSettings, ExperimentConfig};
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::faults::{FaultScript, MigrationPolicyKind};
use aigc_edge::quality::PowerLawQuality;
use aigc_edge::routing::{
    route_trace, route_trace_scan, FleetIndex, RouteContext, Router, RouterKind, ServerState,
};
use aigc_edge::scheduler::Stacking;
use aigc_edge::sim::{
    server_speeds, simulate_event_cluster, simulate_event_cluster_scan, DynamicConfig,
    EventClusterConfig, EventReport,
};
use aigc_edge::trace::{Arrival, ArrivalTrace, PromptMark};
use aigc_edge::util::Pcg64;

fn all_kinds() -> [RouterKind; 5] {
    [
        RouterKind::RoundRobin,
        RouterKind::JoinShortestQueue,
        RouterKind::QualityAware,
        RouterKind::LiveState,
        RouterKind::CacheAware,
    ]
}

fn ctx() -> RouteContext {
    RouteContext { total_bandwidth_hz: 40_000.0, content_bits: 24_000.0 }
}

/// Two identically-configured instances of one policy: stateful
/// routers (round-robin rotation, cache-aware shadows) must evolve in
/// lockstep on the indexed and scan sides for the comparison to mean
/// anything.
fn build_pair(kind: RouterKind) -> (Box<dyn Router>, Box<dyn Router>) {
    let delay = BatchDelayModel::paper();
    let cache = CacheSettings { enabled: true, capacity: 8, ..CacheSettings::default() };
    (kind.build_with_cache(delay, cache), kind.build_with_cache(delay, cache))
}

fn random_probe(rng: &mut Pcg64, id: usize, now: f64) -> Arrival {
    Arrival {
        id,
        t_s: now,
        deadline_s: rng.uniform_in(1.0, 15.0),
        link: Link::new(rng.uniform_in(3.0, 12.0)),
        mark: PromptMark { model: rng.below(3) as u32, prompt: rng.below(9) as u32 },
    }
}

/// Random kill/revive/assign churn, reported to the index exactly as
/// the hot paths report their mutations. Leaves at least one server
/// alive (routing an all-dead fleet is a panic by contract, on both
/// paths).
fn churn(rng: &mut Pcg64, fleet: &mut [ServerState], index: &mut FleetIndex, now: f64) {
    for _ in 0..1 + rng.below(4) {
        let id = rng.below(fleet.len() as u64) as usize;
        match rng.below(6) {
            0 => {
                fleet[id].alive = false;
                index.remove(id);
            }
            1 => {
                fleet[id].alive = true;
                index.touch(&fleet[id]);
            }
            _ => {
                if fleet[id].alive {
                    fleet[id].advance(now);
                    fleet[id].assign(now, rng.uniform_in(0.05, 2.0));
                    index.touch(&fleet[id]);
                }
            }
        }
    }
    if !fleet.iter().any(|s| s.alive) {
        fleet[0].alive = true;
        index.touch(&fleet[0]);
    }
}

#[test]
fn pointwise_indexed_decisions_match_scan_on_random_fleets() {
    let ctx = ctx();
    let delay = BatchDelayModel::paper();
    for n in [1usize, 2, 5, 17, 48] {
        for (k, kind) in all_kinds().into_iter().enumerate() {
            let mut rng = Pcg64::new(0xF0E + n as u64, 11 + k as u64);
            let speeds: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.25, 4.0)).collect();
            let mut fleet = ServerState::fleet(&speeds);
            let mut index = FleetIndex::new(&fleet);
            let (mut idx_router, mut scan_router) = build_pair(kind);
            let mut now = 0.0;
            for round in 0..40 {
                now += rng.uniform_in(0.0, 0.5);
                churn(&mut rng, &mut fleet, &mut index, now);
                let probe = random_probe(&mut rng, round, now);
                let tag = format!("{} n={n} round={round}", kind.name());
                let via_index = idx_router.route_indexed(&probe, &fleet, &ctx, &mut index);
                let via_scan = scan_router.route(&probe, &fleet, &ctx);
                assert_eq!(via_index, via_scan, "{tag}");
                for done in [0u32, 3, 999] {
                    let ri =
                        idx_router.route_resume_indexed(&probe, done, &fleet, &ctx, &mut index);
                    let rs = scan_router.route_resume(&probe, done, &fleet, &ctx);
                    assert_eq!(ri, rs, "{tag} resume credit {done}");
                }
                // Charge the agreed choice so the fleet, the index and
                // both routers' internal state stay in lockstep.
                fleet[via_index].advance(now);
                fleet[via_index].assign(now, delay.g(1) / fleet[via_index].speed);
                index.touch(&fleet[via_index]);
            }
        }
    }
}

fn marked_trace(max_requests: usize, seed: u64) -> ArrivalTrace {
    let cfg = ExperimentConfig::paper();
    let arrival = ArrivalSettings {
        process: ArrivalProcessKind::Poisson,
        rate_hz: 30.0,
        burst_rate_hz: 30.0,
        period_s: 60.0,
        duty: 0.5,
        horizon_s: max_requests as f64,
        max_requests,
        prompt_universe: 64,
        zipf_s: 1.3,
        models: 3,
    };
    ArrivalTrace::generate(&cfg.scenario, &arrival, seed)
}

#[test]
fn route_trace_matches_scan_over_marked_traces() {
    let delay = BatchDelayModel::paper();
    for (n, seed) in [(2usize, 1u64), (7, 2), (33, 3)] {
        let trace = marked_trace(400, seed);
        let speeds = server_speeds(n, 0.5, 2.0);
        for kind in all_kinds() {
            let (mut idx_router, mut scan_router) = build_pair(kind);
            let mut fleet = ServerState::fleet(&speeds);
            let indexed = route_trace(&trace, &mut fleet, idx_router.as_mut(), &delay);
            let mut scan_fleet = ServerState::fleet(&speeds);
            let scan = route_trace_scan(&trace, &mut scan_fleet, scan_router.as_mut(), &delay);
            assert_eq!(indexed, scan, "{} n={n} seed={seed}", kind.name());
        }
    }
}

fn assert_reports_bitwise(a: &EventReport, b: &EventReport, tag: &str) {
    assert_eq!(a.assignment, b.assignment, "{tag}: assignment");
    assert_eq!(a.horizon_s.to_bits(), b.horizon_s.to_bits(), "{tag}: horizon");
    assert_eq!(a.migrations.len(), b.migrations.len(), "{tag}: migration count");
    for (x, y) in a.migrations.iter().zip(&b.migrations) {
        assert_eq!((x.id, x.from, x.to), (y.id, y.from, y.to), "{tag}: migration");
        assert_eq!(x.t_s.to_bits(), y.t_s.to_bits(), "{tag}: migration instant");
    }
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.disposition, y.disposition, "{tag}: request {}", x.id);
        assert_eq!(x.steps, y.steps, "{tag}: request {}", x.id);
        assert_eq!(x.quality.to_bits(), y.quality.to_bits(), "{tag}: request {}", x.id);
        assert_eq!(x.resolved_s.to_bits(), y.resolved_s.to_bits(), "{tag}: request {}", x.id);
    }
}

#[test]
fn engines_bitwise_identical_under_random_fault_scripts() {
    let cfg = ExperimentConfig::paper();
    let scheduler = Stacking::default();
    let allocator = EqualAllocator;
    let delay = BatchDelayModel::paper();
    let quality = PowerLawQuality::paper();
    let pairs = [
        (RouterKind::JoinShortestQueue, MigrationPolicyKind::RequeueOnDeath),
        (RouterKind::QualityAware, MigrationPolicyKind::StealWhenIdle),
        (RouterKind::LiveState, MigrationPolicyKind::Checkpoint),
        (RouterKind::CacheAware, MigrationPolicyKind::Checkpoint),
    ];
    for seed in [3u64, 9] {
        let trace = marked_trace(350, seed);
        let speeds = server_speeds(5, 0.5, 1.75);
        for (router, migration) in pairs {
            let script = FaultScript::random(5, 60.0, 20.0, 7.0, seed + 31);
            let mut dynamic: DynamicConfig = (&cfg.dynamic).into();
            if router == RouterKind::CacheAware {
                dynamic.cache =
                    CacheSettings { enabled: true, capacity: 8, ..CacheSettings::default() };
            }
            let event_cfg = EventClusterConfig {
                speeds: &speeds,
                router,
                dynamic,
                faults: &script,
                migration,
                resume_transfer_s: 0.4,
            };
            let indexed = simulate_event_cluster(
                &trace,
                &scheduler,
                &allocator,
                &delay,
                &quality,
                &event_cfg,
            );
            let scan = simulate_event_cluster_scan(
                &trace,
                &scheduler,
                &allocator,
                &delay,
                &quality,
                &event_cfg,
            );
            assert_reports_bitwise(&indexed, &scan, &format!("{} seed={seed}", router.name()));
        }
    }
}
