//! Integration: the full offline pipeline (workload → PSO bandwidth →
//! STACKING schedule → outcome) reproduces the paper's qualitative
//! claims on the Section-IV scenario.

use aigc_edge::bandwidth::{EqualAllocator, PsoAllocator, PsoConfig};
use aigc_edge::config::ExperimentConfig;
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::quality::{PowerLawQuality, QualityModel};
use aigc_edge::scheduler::{
    validate_schedule, FixedSizeBatching, GreedyBatching, SingleInstance, Stacking,
};
use aigc_edge::sim::{gen_budgets, solve_joint};
use aigc_edge::trace::{generate, sweeps};

fn fast_pso() -> PsoAllocator {
    PsoAllocator::new(PsoConfig { particles: 8, iterations: 12, patience: 6, ..Default::default() })
}

#[test]
fn paper_scenario_feasible_and_valid() {
    let cfg = ExperimentConfig::paper();
    let delay = BatchDelayModel::paper();
    let quality = PowerLawQuality::paper();
    for seed in 0..5 {
        let w = generate(&cfg.scenario, seed);
        let sol = solve_joint(&w, &Stacking::default(), &fast_pso(), &delay, &quality);
        assert_eq!(sol.outcome.outages(), 0, "seed {seed}");
        let services = gen_budgets(&w, &sol.outcome.allocation_hz);
        validate_schedule(&sol.outcome.schedule, &services, &delay).unwrap();
        // every service ends within its deadline
        for s in &sol.outcome.services {
            assert!(s.met, "seed {seed}: {s:?}");
        }
    }
}

#[test]
fn proposed_beats_all_baselines_on_mean_quality() {
    // The paper's headline comparison at K = 20 (Fig. 2b's x = 20 point).
    let cfg = ExperimentConfig::paper();
    let delay = BatchDelayModel::paper();
    let quality = PowerLawQuality::paper();
    let mut wins = 0;
    let trials = 3;
    for seed in 0..trials {
        let w = generate(&cfg.scenario, 100 + seed);
        let proposed = solve_joint(&w, &Stacking::default(), &fast_pso(), &delay, &quality)
            .outcome
            .mean_quality();
        let single = solve_joint(&w, &SingleInstance::default(), &fast_pso(), &delay, &quality)
            .outcome
            .mean_quality();
        let greedy =
            solve_joint(&w, &GreedyBatching, &fast_pso(), &delay, &quality).outcome.mean_quality();
        let fixed = solve_joint(&w, &FixedSizeBatching::default(), &fast_pso(), &delay, &quality)
            .outcome
            .mean_quality();
        assert!(proposed <= single + 1e-9, "seed {seed}: single {single} < proposed {proposed}");
        assert!(proposed <= greedy + 1e-9, "seed {seed}: greedy {greedy} < proposed {proposed}");
        assert!(proposed <= fixed + 1e-9, "seed {seed}: fixed {fixed} < proposed {proposed}");
        // single-instance collapses at K=20: far worse than proposed
        assert!(single > 2.0 * proposed, "seed {seed}: single-instance did not collapse");
        if proposed < greedy && proposed < fixed {
            wins += 1;
        }
    }
    assert!(wins >= 1, "proposed never strictly won in {trials} trials");
}

#[test]
fn bandwidth_optimization_gains_grow_with_tight_deadlines() {
    // Fig. 2c's right-to-left trend: as tau_min tightens, PSO's edge over
    // equal bandwidth grows.
    let cfg = ExperimentConfig::paper();
    let delay = BatchDelayModel::paper();
    let quality = PowerLawQuality::paper();
    let gain_at = |tau_min: f64| -> f64 {
        let scenario = sweeps::with_min_deadline(&cfg.scenario, tau_min);
        let mut total = 0.0;
        for seed in 0..3 {
            let w = generate(&scenario, 200 + seed);
            let pso = solve_joint(&w, &Stacking::default(), &fast_pso(), &delay, &quality)
                .outcome
                .mean_quality();
            let eq = solve_joint(&w, &Stacking::default(), &EqualAllocator, &delay, &quality)
                .outcome
                .mean_quality();
            total += eq - pso; // positive = PSO better (lower FID)
        }
        total / 3.0
    };
    let tight = gain_at(3.0);
    let loose = gain_at(15.0);
    assert!(tight >= -1e-6, "PSO worse than equal under tight deadlines: {tight}");
    assert!(
        tight >= loose - 1e-6,
        "gain should grow as deadlines tighten: tight {tight} vs loose {loose}"
    );
}

#[test]
fn quality_function_agnosticism() {
    // STACKING must work unchanged under a table quality model with no
    // closed form (the paper's "operates independently of any specific
    // form" claim). Build an arbitrary monotone step table.
    struct Steppy;
    impl QualityModel for Steppy {
        fn quality(&self, steps: u32) -> f64 {
            match steps {
                0 => 500.0,
                1..=3 => 300.0,
                4..=8 => 120.0,
                9..=15 => 60.0,
                _ => 25.0,
            }
        }
    }
    let cfg = ExperimentConfig::paper();
    let delay = BatchDelayModel::paper();
    let w = generate(&cfg.scenario, 17);
    let sol = solve_joint(&w, &Stacking::default(), &EqualAllocator, &delay, &Steppy);
    assert_eq!(sol.outcome.outages(), 0);
    let services = gen_budgets(&w, &sol.outcome.allocation_hz);
    validate_schedule(&sol.outcome.schedule, &services, &delay).unwrap();
}
