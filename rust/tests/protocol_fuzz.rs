//! Round-trip and fuzz properties for the TCP wire protocol
//! (`server::protocol`): `parse_request ∘ render` is the identity on
//! valid commands, and no byte salad can panic the parser — malformed
//! lines always map to an `ERR`/`Error(_)` response.

use aigc_edge::prop_assert;
use aigc_edge::server::protocol::{parse_request, Command, Response};
use aigc_edge::util::prop::{forall, Gen};

/// Positive, finite, parseable f64s of many magnitudes.
fn positive_f64(g: &mut Gen) -> f64 {
    let exp = g.f64_in(-6.0, 6.0);
    let mantissa = g.f64_in(0.1, 10.0);
    mantissa * 10f64.powf(exp)
}

#[test]
fn parse_render_identity_on_valid_commands() {
    forall("parse ∘ render == id (GEN)", 400, |g| {
        let cmd = Command::Gen { deadline_s: positive_f64(g), eta: positive_f64(g) };
        let parsed = parse_request(&cmd.render());
        prop_assert!(g, parsed == Ok(cmd.clone()), "{:?} -> {:?}", cmd.render(), parsed);
        true
    });
    assert_eq!(parse_request(&Command::Stats.render()), Ok(Command::Stats));
    assert_eq!(parse_request(&Command::Quit.render()), Ok(Command::Quit));
}

#[test]
fn response_render_parse_identity() {
    forall("Response round-trip", 300, |g| {
        let resp = Response::Done {
            steps: g.usize_in(1, 1000) as u32,
            gen_ms: positive_f64(g),
            tx_ms: positive_f64(g),
            quality: positive_f64(g),
        };
        let parsed = Response::parse(&resp.render());
        // Done renders with fixed precision, so compare within it.
        match (parsed, resp) {
            (
                Ok(Response::Done { steps: s2, gen_ms: g2, tx_ms: t2, quality: q2 }),
                Response::Done { steps, gen_ms, tx_ms, quality },
            ) => {
                prop_assert!(g, s2 == steps, "steps {s2} != {steps}");
                let gen_ok = (g2 - gen_ms).abs() <= 1e-3 + gen_ms * 1e-9;
                prop_assert!(g, gen_ok, "gen {g2} vs {gen_ms}");
                prop_assert!(g, (t2 - tx_ms).abs() <= 1e-3 + tx_ms * 1e-9, "tx {t2} vs {tx_ms}");
                let q_ok = (q2 - quality).abs() <= 1e-4 + quality * 1e-9;
                prop_assert!(g, q_ok, "q {q2} vs {quality}");
            }
            (other, resp) => prop_assert!(g, false, "{resp:?} -> {other:?}"),
        }
        true
    });
    assert_eq!(Response::parse(&Response::Outage.render()), Ok(Response::Outage));
    assert_eq!(
        Response::parse(&Response::Error("boom with spaces".into()).render()),
        Ok(Response::Error("boom with spaces".into()))
    );
}

/// Arbitrary line content: printable ASCII, unicode (incl. multibyte
/// whitespace), embedded separators, near-miss keywords.
fn fuzz_line(g: &mut Gen) -> String {
    let alphabet: &[&str] = &[
        "GEN", "GE", "GENX", "STATS", "QUIT", "DONE", "OUTAGE", "ERR", "-1", "0", "1.5",
        "nan", "NaN", "inf", "-inf", "1e309", "5", "6.5", " ", "\t", "\u{a0}", "\u{2003}",
        "日本", "é", "--", ",", "..", "7..2", "+3", "0x10", "", "\u{0}",
    ];
    let parts = g.usize_in(0, 8);
    let mut line = String::new();
    for _ in 0..parts {
        line.push_str(g.pick(alphabet));
        if g.bool() {
            line.push(' ');
        }
    }
    line
}

#[test]
fn fuzzed_lines_never_panic_and_malformed_maps_to_error() {
    forall("parse_request never panics", 600, |g| {
        let line = fuzz_line(g);
        match parse_request(&line) {
            Ok(cmd) => {
                // Anything accepted must round-trip to itself.
                let again = parse_request(&cmd.render());
                prop_assert!(g, again == Ok(cmd.clone()), "{line:?} -> {cmd:?} -> {again:?}");
            }
            Err(msg) => {
                // The server's reply for a malformed line is an ERR
                // response; it must render and stay an Error on parse.
                let rendered = Response::Error(msg.clone()).render();
                prop_assert!(g, rendered.starts_with("ERR"), "{rendered:?}");
                let back = Response::parse(&rendered);
                prop_assert!(
                    g,
                    matches!(back, Ok(Response::Error(_))),
                    "{line:?}: {back:?}"
                );
            }
        }
        true
    });
}

#[test]
fn fuzzed_response_lines_never_panic() {
    forall("Response::parse never panics", 600, |g| {
        let line = fuzz_line(g);
        // Any outcome is fine — absence of panics and of misparsed
        // `Done` with non-finite fields is the property.
        if let Ok(Response::Done { gen_ms, tx_ms, quality, .. }) = Response::parse(&line) {
            prop_assert!(
                g,
                !gen_ms.is_nan() || line.to_lowercase().contains("nan"),
                "NaN from {line:?}: {gen_ms}"
            );
            let _ = (tx_ms, quality);
        }
        true
    });
}

#[test]
fn gen_rejects_nonpositive_and_nonfinite() {
    for bad in [
        "GEN 0 5",
        "GEN 5 0",
        "GEN -1 5",
        "GEN 5 -2",
        "GEN nan 5",
        "GEN 5 nan",
    ] {
        assert!(parse_request(bad).is_err(), "accepted {bad:?}");
    }
    // inf parses as f64 but violates nothing numeric downstream guards
    // against except positivity — it is > 0, so it is accepted today;
    // pin that so a future change is a conscious one.
    assert!(parse_request("GEN inf 5").is_ok());
}
