//! Integration guards for the constant-memory metrics path (ISSUE 6):
//! streaming percentiles must replay bit-identically across seeds and
//! thread counts, track exact percentiles within the documented rank
//! budget on dissimilar delay distributions, and the columnar trace
//! must round-trip bit-identically with the CSV path — including
//! straight through the streaming engine without ever materializing
//! the `Vec<Arrival>`.

use aigc_edge::bandwidth::EqualAllocator;
use aigc_edge::config::{ArrivalProcessKind, ArrivalSettings, ExperimentConfig};
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::metrics::{MetricsMode, OutcomeAccumulator, OutcomeStats};
use aigc_edge::quality::PowerLawQuality;
use aigc_edge::routing::RouterKind;
use aigc_edge::scheduler::Stacking;
use aigc_edge::sim::{
    server_speeds, simulate_cluster, simulate_dynamic, simulate_dynamic_streaming, ClusterConfig,
    DynamicConfig,
};
use aigc_edge::trace::columnar::{decode, encode_chunked};
use aigc_edge::trace::{ArrivalTrace, ColumnarReader};
use aigc_edge::util::stats::QuantileSketch;
use aigc_edge::util::Pcg64;

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];
const EPS: f64 = 0.02;

fn trace(rate: f64, horizon: f64, seed: u64) -> ArrivalTrace {
    let cfg = ExperimentConfig::paper();
    let arrival = ArrivalSettings {
        process: ArrivalProcessKind::Poisson,
        rate_hz: rate,
        burst_rate_hz: rate,
        period_s: 60.0,
        duty: 0.5,
        horizon_s: horizon,
        max_requests: 0,
        prompt_universe: 1,
        zipf_s: 1.0,
        models: 1,
    };
    ArrivalTrace::generate(&cfg.scenario, &arrival, seed)
}

fn stats_bits(s: &OutcomeStats) -> [u64; 6] {
    [
        s.mean_quality.to_bits(),
        s.outage_rate.to_bits(),
        s.p50_e2e_s.to_bits(),
        s.p95_e2e_s.to_bits(),
        s.p99_e2e_s.to_bits(),
        s.mean_wait_s.to_bits(),
    ]
}

fn stream_stats(t: &ArrivalTrace, threads: usize) -> (usize, usize, OutcomeStats) {
    let quality = PowerLawQuality::paper();
    let delay = BatchDelayModel::paper();
    let scheduler = Stacking::default();
    let mut dyn_cfg = DynamicConfig::default();
    dyn_cfg.threads = threads;
    let report = simulate_dynamic_streaming(
        t.arrivals.iter().copied(),
        t.total_bandwidth_hz,
        t.content_bits,
        &scheduler,
        &EqualAllocator,
        &delay,
        &quality,
        &dyn_cfg,
        OutcomeAccumulator::streaming(EPS),
    );
    (report.count(), report.served(), report.stats())
}

/// The GK sketch has no randomness and no clocks, so the entire
/// streaming pipeline is a pure function of the seeded arrival stream:
/// identical bits on every rerun, at every solver thread count.
#[test]
fn streaming_stats_bitwise_identical_across_seeds_and_thread_counts() {
    for seed in [7u64, 11, 42] {
        let t = trace(6.0, 60.0, seed);
        let (count, served, reference) = stream_stats(&t, 1);
        assert!(count > 0 && served > 0, "seed {seed}: empty run");
        let (c2, s2, again) = stream_stats(&t, 1);
        assert_eq!((count, served), (c2, s2), "seed {seed}: replay diverged");
        assert_eq!(stats_bits(&reference), stats_bits(&again), "seed {seed}: replay diverged");
        for threads in THREAD_COUNTS {
            let (ct, st, got) = stream_stats(&t, threads);
            assert_eq!((count, served), (ct, st), "seed {seed} threads={threads}");
            assert_eq!(stats_bits(&reference), stats_bits(&got), "seed {seed} threads={threads}");
        }
    }
}

/// Fleet-level streaming summaries (per-server sketches combined by
/// tandem rank walks) inherit the same thread-count invariance.
#[test]
fn cluster_fleet_streaming_stats_identical_across_thread_counts() {
    let t = trace(6.0, 40.0, 7);
    let quality = PowerLawQuality::paper();
    let delay = BatchDelayModel::paper();
    let scheduler = Stacking::default();
    let run = |threads: usize| {
        let mut dynamic = DynamicConfig::default();
        dynamic.threads = threads;
        let cfg = ClusterConfig {
            speeds: server_speeds(3, 0.5, 1.5),
            router: RouterKind::JoinShortestQueue,
            dynamic,
        };
        let report = simulate_cluster(&t, &scheduler, &EqualAllocator, &delay, &quality, &cfg);
        report.fleet_stats_with(MetricsMode::Streaming, EPS)
    };
    let reference = run(1);
    assert!(reference.count > 0 && reference.served > 0);
    for threads in THREAD_COUNTS {
        let got = run(threads);
        assert_eq!(
            (reference.count, reference.served),
            (got.count, got.served),
            "threads={threads}"
        );
        assert_eq!(stats_bits(&reference), stats_bits(&got), "threads={threads}");
    }
}

fn samples(name: &str, n: usize) -> Vec<f64> {
    let mut rng = Pcg64::seeded(99);
    (0..n)
        .map(|_| match name {
            "uniform" => rng.uniform(),
            "exponential" => rng.exponential(0.7),
            _ => {
                // bimodal: two well-separated uniform humps
                if rng.uniform() < 0.5 {
                    1.0 + rng.uniform()
                } else {
                    10.0 + 3.0 * rng.uniform()
                }
            }
        })
        .collect()
}

/// Rank-error contract on shapes the e2e-delay distribution actually
/// takes: every reported quantile is an inserted value within
/// `⌈eps·n⌉ + 1` ranks of the exact target, even across the bimodal
/// gap where value-space error would be huge.
#[test]
fn sketch_tracks_exact_percentiles_on_dissimilar_distributions() {
    let n = 30_000usize;
    let budget = (EPS * n as f64).ceil() as u64 + 1;
    for name in ["uniform", "exponential", "bimodal"] {
        let xs = samples(name, n);
        let mut sketch = QuantileSketch::new(EPS);
        for &x in &xs {
            sketch.insert(x);
        }
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [1.0, 10.0, 50.0, 90.0, 95.0, 99.0, 99.9] {
            let v = sketch.quantile(p);
            let target = ((p / 100.0) * n as f64).ceil().max(1.0) as u64;
            let lo = sorted.partition_point(|&x| x < v) as u64 + 1;
            let hi = sorted.partition_point(|&x| x <= v) as u64;
            assert!(lo <= hi, "{name} p{p}: value {v} was never inserted");
            let dist = if target < lo {
                lo - target
            } else if target > hi {
                target - hi
            } else {
                0
            };
            assert!(dist <= budget, "{name} p{p}: {dist} ranks off target (budget {budget})");
        }
    }
}

/// The binary columnar format and the CSV format decode to the same
/// bits, and the chunked `ColumnarReader` drives the streaming engine
/// to the same tallies and bit-identical percentiles as the exact
/// engine on the materialized trace.
#[test]
fn columnar_replay_matches_csv_and_feeds_the_streaming_engine() {
    let t = trace(5.0, 90.0, 7);
    assert!(t.len() > 100, "seed-7 stream too small to be meaningful");
    let via_csv = ArrivalTrace::from_csv(&t.to_csv()).unwrap();
    let bytes = encode_chunked(&t, 64);
    let via_columnar = decode(&bytes).unwrap();
    assert_eq!(via_csv, via_columnar, "CSV and columnar round-trips diverged");

    let quality = PowerLawQuality::paper();
    let delay = BatchDelayModel::paper();
    let scheduler = Stacking::default();
    let dyn_cfg = DynamicConfig::default();
    let exact = simulate_dynamic(&t, &scheduler, &EqualAllocator, &delay, &quality, &dyn_cfg);
    let reader = ColumnarReader::new(&bytes).unwrap();
    let streamed = simulate_dynamic_streaming(
        reader.map(|a| a.expect("valid frame")),
        t.total_bandwidth_hz,
        t.content_bits,
        &scheduler,
        &EqualAllocator,
        &delay,
        &quality,
        &dyn_cfg,
        OutcomeAccumulator::exact(),
    );
    assert_eq!(streamed.count(), exact.outcomes.len());
    assert_eq!(streamed.served(), exact.served());
    let stats = streamed.stats();
    for (p, got) in [(50.0, stats.p50_e2e_s), (95.0, stats.p95_e2e_s), (99.0, stats.p99_e2e_s)] {
        assert_eq!(got.to_bits(), exact.e2e_percentile(p).to_bits(), "p{p}");
    }
    assert_eq!(streamed.horizon_s.to_bits(), exact.horizon_s.to_bits());
}
