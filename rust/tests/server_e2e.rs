//! Integration: the TCP server serves real generation requests through
//! the full stack (protocol → epoch batcher → STACKING + PSO → PJRT).

use aigc_edge::config::{default_artifacts_dir, ExperimentConfig};
use aigc_edge::server::{serve, Client, Response, ServerConfig};

#[test]
fn tcp_round_trip_with_batched_epoch() {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut cfg = ExperimentConfig::paper();
    // keep the epoch solve fast
    cfg.pso.particles = 4;
    cfg.pso.iterations = 4;
    let server = serve(
        dir,
        cfg,
        ServerConfig { epoch_ms: 150, max_batch: 8 },
        "127.0.0.1:0",
    )
    .expect("server start");
    let addr = server.addr;

    // Three concurrent clients land in the same epoch and are batch-served.
    let handles: Vec<_> = (0..3)
        .map(|i| {
            std::thread::spawn(move || {
                let mut client = Client::connect(addr).expect("connect");
                // short deadlines keep step counts (and test time) small
                client.generate(2.0 + i as f64 * 0.5, 6.0 + i as f64).expect("generate")
            })
        })
        .collect();
    let responses: Vec<Response> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for r in &responses {
        match r {
            Response::Done { steps, gen_ms, tx_ms, quality } => {
                assert!(*steps > 0);
                assert!(*gen_ms > 0.0);
                assert!(*tx_ms > 0.0);
                assert!(*quality > 0.0);
            }
            other => panic!("expected DONE, got {other:?}"),
        }
    }

    // Metrics snapshot over the same connection protocol.
    let mut client = Client::connect(addr).unwrap();
    // Submit one more so the stats snapshot is non-trivial even if the
    // first epoch's render raced.
    let _ = client.generate(2.0, 7.0).unwrap();
    let stats = client.stats().unwrap();
    assert!(stats.contains("counter requests"), "stats:\n{stats}");
    assert!(stats.contains("latency batch_exec"), "stats:\n{stats}");

    // Malformed input gets an ERR, connection stays usable.
    use std::io::{BufRead, BufReader, Write};
    let mut raw = std::net::TcpStream::connect(addr).unwrap();
    writeln!(raw, "BOGUS nonsense").unwrap();
    let mut line = String::new();
    BufReader::new(raw.try_clone().unwrap()).read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR"), "{line}");
}
