//! Failure injection: corrupted artifacts, malformed manifests, bad
//! protocol input, and infeasible configurations must fail *loudly and
//! precisely*, never silently (the NaN-elision incident in §Perf is the
//! motivating war story).

use std::io::Write;

use aigc_edge::config::ExperimentConfig;
use aigc_edge::runtime::{ArtifactStore, Manifest};

fn tmpdir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("aigc-edge-test-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn missing_manifest_is_actionable() {
    let dir = tmpdir("missing");
    let Err(err) = ArtifactStore::load(&dir) else { panic!("load should fail") };
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "error must tell the user what to run: {msg}");
}

#[test]
fn manifest_referencing_missing_hlo_fails() {
    let dir = tmpdir("nohlo");
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"data_dim": 64, "num_train_steps": 1000, "buckets": [1],
            "hlo": {"1": {"file": "denoise_b1.hlo.txt"}}}"#,
    )
    .unwrap();
    let Err(err) = ArtifactStore::load(&dir) else { panic!("load should fail") };
    assert!(format!("{err:#}").contains("denoise_b1.hlo.txt"), "{err:#}");
}

#[test]
fn corrupt_hlo_text_fails_at_parse() {
    let dir = tmpdir("corrupt");
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"data_dim": 64, "num_train_steps": 1000, "buckets": [1],
            "hlo": {"1": {"file": "denoise_b1.hlo.txt"}}}"#,
    )
    .unwrap();
    let mut f = std::fs::File::create(dir.join("denoise_b1.hlo.txt")).unwrap();
    writeln!(f, "HloModule garbage\nthis is not hlo").unwrap();
    let Err(err) = ArtifactStore::load(&dir) else { panic!("load should fail") };
    let msg = format!("{err:#}");
    assert!(msg.contains("parsing HLO text") || msg.contains("Syntax"), "{msg}");
}

#[test]
fn truncated_real_artifact_detected() {
    // Take the real manifest but truncate one HLO file in a copy.
    let real = aigc_edge::config::default_artifacts_dir();
    if !real.join("manifest.json").exists() {
        return;
    }
    let dir = tmpdir("truncated");
    std::fs::copy(real.join("manifest.json"), dir.join("manifest.json")).unwrap();
    let manifest = Manifest::load(&real.join("manifest.json")).unwrap();
    for (bucket, file) in &manifest.hlo_files {
        let content = std::fs::read_to_string(real.join(file)).unwrap();
        if *bucket == 1 {
            // chop mid-instruction
            std::fs::write(dir.join(file), &content[..content.len() / 2]).unwrap();
        } else {
            std::fs::write(dir.join(file), &content).unwrap();
        }
    }
    let Err(err) = ArtifactStore::load(&dir) else { panic!("load should fail") };
    assert!(format!("{err:#}").contains("parsing HLO text"), "{err:#}");
}

#[test]
fn config_rejects_semantic_nonsense() {
    for bad in [
        "[scenario]\nnum_services = 0",
        "[scenario]\ndeadline_lo = -1.0",
        "[scenario]\ndeadline_lo = 10.0\ndeadline_hi = 5.0",
        "[scenario]\ntotal_bandwidth_hz = 0",
        "[scenario]\ncontent_bits = -5.0",
        "[delay]\na = -0.1",
        "[stacking]\nmax_steps = 0",
        "typo_key = 1",
        "[quality]\nmodel = \"nonexistent\"",
    ] {
        assert!(ExperimentConfig::from_toml_text(bad).is_err(), "accepted: {bad}");
    }
}

#[test]
fn zero_bandwidth_service_is_outage_not_panic() {
    use aigc_edge::delay::BatchDelayModel;
    use aigc_edge::quality::PowerLawQuality;
    use aigc_edge::scheduler::Stacking;
    use aigc_edge::sim::evaluate;
    use aigc_edge::trace::generate;
    let cfg = ExperimentConfig::paper();
    let w = generate(&cfg.scenario, 1);
    let mut alloc = vec![w.total_bandwidth_hz / w.k() as f64; w.k()];
    alloc[3] = 0.0; // infinite tx delay
    let out = evaluate(
        &w,
        &alloc,
        &Stacking::default(),
        &BatchDelayModel::paper(),
        &PowerLawQuality::paper(),
    );
    assert_eq!(out.services[3].steps, 0);
    assert!(!out.services[3].met);
    assert!(out.services.iter().filter(|s| s.id != 3).all(|s| s.met));
}

#[test]
fn nan_and_extreme_budgets_never_panic_schedulers() {
    use aigc_edge::delay::BatchDelayModel;
    use aigc_edge::quality::PowerLawQuality;
    use aigc_edge::scheduler::{all_schedulers, Service};
    let delay = BatchDelayModel::paper();
    let quality = PowerLawQuality::paper();
    let services: Vec<Service> = vec![
        Service::new(0, f64::NEG_INFINITY),
        Service::new(1, -1e18),
        Service::new(2, 0.0),
        Service::new(3, 1e-12),
        Service::new(4, 1e6), // huge but finite budget (caps at max_steps)
    ];
    for sched in all_schedulers() {
        let s = sched.schedule(&services, &delay, &quality);
        assert_eq!(s.steps.len(), services.len(), "{}", sched.name());
        assert_eq!(s.steps[0], 0);
        assert_eq!(s.steps[1], 0);
        assert_eq!(s.steps[2], 0);
    }
}
