//! Property suite for the (P2) schedulers: every scheduler must emit
//! constraint-clean schedules (Eqs. 2, 6, 7, 14 — machine-checked by
//! `scheduler::validate`) over randomized workloads, and STACKING must
//! dominate the baselines instance-by-instance.
//!
//! Workloads are drawn wider than the paper's Section-IV regime
//! (including infeasible budgets ≤ 0 and knife-edge budgets near g(1))
//! so the invariants hold off the happy path too.

use aigc_edge::delay::BatchDelayModel;
use aigc_edge::prop_assert;
use aigc_edge::quality::PowerLawQuality;
use aigc_edge::scheduler::{
    all_schedulers, validate_schedule, BatchScheduler, FixedSizeBatching, GreedyBatching,
    Service, SingleInstance, Stacking,
};
use aigc_edge::util::prop::{forall, Gen};

fn random_services(g: &mut Gen) -> Vec<Service> {
    let k = g.usize_in(1, 24);
    (0..k)
        .map(|i| {
            // Mix regimes: infeasible, knife-edge around g(1)/g(2),
            // and comfortable paper-like budgets.
            let budget = match g.usize_in(0, 9) {
                0 => g.f64_in(-2.0, 0.1),
                1 | 2 => g.f64_in(0.3, 0.9),
                _ => g.f64_in(1.0, 20.0),
            };
            Service::new(i, budget)
        })
        .collect()
}

fn random_delay(g: &mut Gen) -> BatchDelayModel {
    BatchDelayModel::new(g.f64_in(0.005, 0.2), g.f64_in(0.05, 1.0))
}

/// Each scheduler × ≥200 random workloads: the schedule must satisfy
/// the full constraint system.
#[test]
fn stacking_always_emits_valid_schedules() {
    scheduler_validity(&Stacking::default(), "stacking");
}

#[test]
fn greedy_always_emits_valid_schedules() {
    scheduler_validity(&GreedyBatching, "greedy");
}

#[test]
fn fixed_size_always_emits_valid_schedules() {
    scheduler_validity(&FixedSizeBatching::default(), "fixed-size");
}

#[test]
fn single_instance_always_emits_valid_schedules() {
    scheduler_validity(&SingleInstance::default(), "single-instance");
}

fn scheduler_validity(scheduler: &dyn BatchScheduler, tag: &str) {
    let quality = PowerLawQuality::paper();
    forall(&format!("{tag} emits constraint-clean schedules"), 220, |g| {
        let services = random_services(g);
        let delay = random_delay(g);
        let schedule = scheduler.schedule(&services, &delay, &quality);
        prop_assert!(
            g,
            schedule.steps.len() == services.len(),
            "{tag}: steps arity {} vs {}",
            schedule.steps.len(),
            services.len()
        );
        prop_assert!(
            g,
            schedule.completion.len() == services.len(),
            "{tag}: completion arity mismatch"
        );
        let verdict = validate_schedule(&schedule, &services, &delay);
        prop_assert!(
            g,
            verdict.is_ok(),
            "{tag}: {:?}\n  services={services:?}\n  delay={delay:?}",
            verdict
        );
        // Infeasible services must be outages, never phantom steps.
        for (svc, &steps) in services.iter().zip(&schedule.steps) {
            if svc.gen_budget < delay.g(1) {
                prop_assert!(
                    g,
                    steps == 0,
                    "{tag}: service {} got {steps} steps on budget {}",
                    svc.id,
                    svc.gen_budget
                );
            }
        }
        true
    });
}

/// STACKING's mean quality is at least as good as SingleInstance's on
/// *every* sampled instance (lower FID = better; the dominance guard in
/// `Stacking::schedule` makes this exact, not statistical).
#[test]
fn stacking_dominates_single_instance_everywhere() {
    let quality = PowerLawQuality::paper();
    forall("stacking <= single-instance", 250, |g| {
        let services = random_services(g);
        let delay = random_delay(g);
        let st = Stacking::default().schedule(&services, &delay, &quality).mean_quality(&quality);
        let si = SingleInstance::default()
            .schedule(&services, &delay, &quality)
            .mean_quality(&quality);
        prop_assert!(g, st <= si + 1e-9, "stacking {st} > single {si}\n  {services:?}");
        true
    });
}

/// Same instance-wise dominance over greedy and fixed-size batching.
#[test]
fn stacking_dominates_naive_batching_everywhere() {
    let quality = PowerLawQuality::paper();
    forall("stacking <= greedy", 250, |g| {
        let services = random_services(g);
        let delay = random_delay(g);
        let st = Stacking::default().schedule(&services, &delay, &quality).mean_quality(&quality);
        let gr = GreedyBatching.schedule(&services, &delay, &quality).mean_quality(&quality);
        prop_assert!(g, st <= gr + 1e-9, "stacking {st} > greedy {gr}\n  {services:?}");
        true
    });
}

/// Schedulers are pure functions of their inputs: same workload, same
/// schedule (bit-identical) — the invariant every golden fixture and
/// replayable simulation rests on.
#[test]
fn schedulers_are_deterministic() {
    let quality = PowerLawQuality::paper();
    forall("schedulers deterministic", 60, |g| {
        let services = random_services(g);
        let delay = random_delay(g);
        for sched in all_schedulers() {
            let a = sched.schedule(&services, &delay, &quality);
            let b = sched.schedule(&services, &delay, &quality);
            prop_assert!(g, a == b, "{} differs across runs", sched.name());
        }
        true
    });
}

/// Mean quality can never beat the best possible step count allowed by
/// the budget (floor(budget / g(1)) steps, each run alone) — a sanity
/// bound no scheduler may violate.
#[test]
fn no_scheduler_beats_the_singleton_bound() {
    let quality = PowerLawQuality::paper();
    forall("per-service singleton upper bound", 120, |g| {
        let services = random_services(g);
        let delay = random_delay(g);
        for sched in all_schedulers() {
            let schedule = sched.schedule(&services, &delay, &quality);
            for (svc, &steps) in services.iter().zip(&schedule.steps) {
                // small epsilon absorbs float accumulation at exact
                // budget/g(1) boundaries
                let bound = if svc.gen_budget <= 0.0 {
                    0
                } else {
                    (svc.gen_budget / delay.g(1) + 1e-6).floor() as u32
                };
                prop_assert!(
                    g,
                    steps <= bound.max(0),
                    "{}: service {} did {steps} steps, singleton bound {bound} (budget {})",
                    sched.name(),
                    svc.id,
                    svc.gen_budget
                );
            }
        }
        true
    });
}
