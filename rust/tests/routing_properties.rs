//! Property suite for the cluster routing layer (ISSUE 2): randomized
//! traces and fleets through every `Router` policy, asserting the
//! dispatch invariants the cluster simulator depends on.
//!
//! Invariants (each over ≥ 200 randomized traces):
//! * **conservation** — every arrival is routed exactly once, to a
//!   valid server index;
//! * **liveness respect** — no request is ever routed to a server
//!   marked failed;
//! * **determinism** — identical seed (trace + fleet + policy) implies
//!   an identical per-server assignment;
//! * **JSQ minimality** — join-shortest-queue never routes to a server
//!   with strictly more outstanding work than some alive alternative;
//! * **total_cmp pin (ISSUE 10)** — the router scans' migration from
//!   `partial_cmp(..).unwrap()` to `f64::total_cmp` reorders nothing
//!   on the finite, non-negative keys those comparators actually see.

use aigc_edge::channel::Link;
use aigc_edge::config::{ArrivalProcessKind, ArrivalSettings, ExperimentConfig};
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::prop_assert;
use aigc_edge::routing::{route_trace, RouteContext, RouterKind, ServerState};
use aigc_edge::trace::{Arrival, ArrivalTrace, PromptMark};
use aigc_edge::util::prop::{forall, Gen};

/// A random small trace: Poisson or burst, a handful of seconds long.
fn random_trace(g: &mut Gen) -> ArrivalTrace {
    let mut scenario = ExperimentConfig::paper().scenario;
    scenario.deadline_lo = g.f64_in(1.0, 6.0);
    scenario.deadline_hi = scenario.deadline_lo + g.f64_in(1.0, 15.0);
    let burst = g.bool();
    let rate = g.f64_in(0.5, 10.0);
    let arrival = ArrivalSettings {
        process: if burst { ArrivalProcessKind::Burst } else { ArrivalProcessKind::Poisson },
        rate_hz: rate,
        burst_rate_hz: rate * g.f64_in(1.0, 4.0),
        period_s: g.f64_in(2.0, 20.0),
        duty: g.f64_in(0.1, 1.0),
        horizon_s: g.f64_in(3.0, 15.0),
        max_requests: 0,
        prompt_universe: 1,
        zipf_s: 1.0,
        models: 1,
    };
    ArrivalTrace::generate(&scenario, &arrival, g.u64())
}

/// A random fleet: 1–6 servers, heterogeneous speeds, some failed (at
/// least one alive).
fn random_fleet(g: &mut Gen) -> Vec<ServerState> {
    let n = g.usize_in(1, 6);
    let speeds = g.vec_of(n, |g| g.f64_in(0.3, 2.5));
    let mut fleet = ServerState::fleet(&speeds);
    for s in fleet.iter_mut() {
        if g.f64_in(0.0, 1.0) < 0.3 {
            s.alive = false;
        }
    }
    let alive = g.usize_in(0, n - 1);
    fleet[alive].alive = true; // guarantee at least one alive server
    fleet
}

fn clone_fleet(fleet: &[ServerState]) -> Vec<ServerState> {
    fleet.to_vec()
}

#[test]
fn every_arrival_routed_exactly_once_and_never_to_failed() {
    forall("routing conservation + liveness", 250, |g| {
        let trace = random_trace(g);
        let fleet = random_fleet(g);
        let kind = *g.pick(&RouterKind::all());
        let delay = BatchDelayModel::paper();
        let mut servers = clone_fleet(&fleet);
        let assignment = route_trace(&trace, &mut servers, kind.build(delay).as_mut(), &delay);
        prop_assert!(
            g,
            assignment.len() == trace.len(),
            "{}: {} assignments for {} arrivals",
            kind.name(),
            assignment.len(),
            trace.len()
        );
        for (id, &server) in assignment.iter().enumerate() {
            prop_assert!(g, server < fleet.len(), "{}: server {server} out of range", kind.name());
            prop_assert!(
                g,
                fleet[server].alive,
                "{}: arrival {id} routed to failed server {server}",
                kind.name()
            );
        }
        // conservation: per-server routed counts partition the trace
        let routed: usize = servers.iter().map(|s| s.routed).sum();
        prop_assert!(
            g,
            routed == trace.len(),
            "{}: routed {routed} != {} arrivals",
            kind.name(),
            trace.len()
        );
        for s in &servers {
            prop_assert!(g, s.alive || s.routed == 0, "failed server {} got traffic", s.id);
        }
        true
    });
}

#[test]
fn identical_seed_gives_identical_assignment() {
    forall("routing determinism", 200, |g| {
        let trace = random_trace(g);
        let fleet = random_fleet(g);
        let kind = *g.pick(&RouterKind::all());
        let delay = BatchDelayModel::paper();
        let mut fleet_a = clone_fleet(&fleet);
        let mut fleet_b = clone_fleet(&fleet);
        let a = route_trace(&trace, &mut fleet_a, kind.build(delay).as_mut(), &delay);
        let b = route_trace(&trace, &mut fleet_b, kind.build(delay).as_mut(), &delay);
        prop_assert!(g, a == b, "{}: same inputs, different assignments", kind.name());
        true
    });
}

#[test]
fn jsq_never_routes_to_a_strictly_longer_queue() {
    forall("jsq minimality", 200, |g| {
        let trace = random_trace(g);
        let mut servers = random_fleet(g);
        let delay = BatchDelayModel::paper();
        let mut router = RouterKind::JoinShortestQueue.build(delay);
        let ctx = RouteContext {
            total_bandwidth_hz: trace.total_bandwidth_hz,
            content_bits: trace.content_bits,
        };
        for arrival in &trace.arrivals {
            for s in servers.iter_mut() {
                s.advance(arrival.t_s);
            }
            let choice = router.route(arrival, &servers, &ctx);
            let chosen_work = servers[choice].outstanding_work_s(arrival.t_s);
            let min_work = servers
                .iter()
                .filter(|s| s.alive)
                .map(|s| s.outstanding_work_s(arrival.t_s))
                .fold(f64::INFINITY, f64::min);
            prop_assert!(
                g,
                chosen_work <= min_work + 1e-9,
                "arrival {}: jsq picked {:.6}s of work, {:.6}s was available",
                arrival.id,
                chosen_work,
                min_work
            );
            let est = delay.g(1) / servers[choice].speed;
            servers[choice].assign(arrival.t_s, est);
        }
        true
    });
}

#[test]
fn total_cmp_matches_partial_cmp_on_router_comparator_inputs() {
    // ISSUE 10 migrated every router scan from the NaN-panicking
    // `partial_cmp(..).unwrap()` to `f64::total_cmp`. On the values
    // those comparators actually see — finite, non-negative work /
    // backlog seconds, never -0.0 (IEEE subtraction of equal operands
    // yields +0.0, and the clamp's other arm is the +0.0 literal) —
    // the two orders coincide; pinning that equivalence means the swap
    // can never reorder a scan.
    forall("total_cmp == partial_cmp on finite non-negative keys", 400, |g| {
        let sample = |g: &mut Gen| -> f64 {
            match g.usize_in(0, 3) {
                0 => 0.0,
                1 => g.usize_in(0, 12) as f64 * 0.25, // lattice: frequent exact ties
                _ => g.f64_in(0.0, 50.0),
            }
        };
        let a = sample(g);
        let b = sample(g);
        prop_assert!(
            g,
            a.total_cmp(&b) == a.partial_cmp(&b).unwrap(),
            "total_cmp({a}, {b}) = {:?} but partial_cmp = {:?}",
            a.total_cmp(&b),
            a.partial_cmp(&b).unwrap()
        );
        true
    });
}

#[test]
fn jsq_scan_order_pinned_to_the_partial_cmp_reference() {
    // The executable half of the pin: on random busy/failed fleets,
    // the total_cmp JSQ scan must pick exactly the server the
    // pre-ISSUE-10 `partial_cmp(..).unwrap()` argmin picks.
    forall("jsq total_cmp scan == partial_cmp argmin", 250, |g| {
        let mut servers = random_fleet(g);
        let delay = BatchDelayModel::paper();
        let mut router = RouterKind::JoinShortestQueue.build(delay);
        let ctx = RouteContext { total_bandwidth_hz: 40_000.0, content_bits: 24_000.0 };
        let mut now = 0.0;
        for round in 0..25usize {
            now += g.f64_in(0.0, 0.4);
            let id = g.usize_in(0, servers.len() - 1);
            if servers[id].alive && g.bool() {
                servers[id].advance(now);
                servers[id].assign(now, g.f64_in(0.05, 1.5));
            }
            let probe = Arrival {
                id: round,
                t_s: now,
                deadline_s: 5.0,
                link: Link::new(7.0),
                mark: PromptMark::ZERO,
            };
            let choice = router.route(&probe, &servers, &ctx);
            let reference = servers
                .iter()
                .filter(|s| s.alive)
                .map(|s| (s.outstanding_work_s(now), s.id))
                .min_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)))
                .map(|(_, id)| id)
                .unwrap();
            prop_assert!(
                g,
                choice == reference,
                "round {round}: total_cmp scan chose {choice}, partial_cmp argmin {reference}"
            );
        }
        true
    });
}

#[test]
fn quality_aware_beats_round_robin_on_predicted_outages() {
    // Not a per-arrival invariant but a sanity property of the marginal
    // estimator: on a fleet with one very slow server, quality-aware
    // sends it less traffic than blind round-robin does.
    forall("quality-aware shifts load off slow servers", 50, |g| {
        let trace = random_trace(g);
        if trace.len() < 20 {
            return true; // too small to compare shares meaningfully
        }
        let speeds = [0.3, 1.5, 1.5];
        let delay = BatchDelayModel::paper();
        let mut rr_fleet = ServerState::fleet(&speeds);
        let mut qa_fleet = ServerState::fleet(&speeds);
        route_trace(&trace, &mut rr_fleet, RouterKind::RoundRobin.build(delay).as_mut(), &delay);
        route_trace(&trace, &mut qa_fleet, RouterKind::QualityAware.build(delay).as_mut(), &delay);
        prop_assert!(
            g,
            qa_fleet[0].routed <= rr_fleet[0].routed + 1,
            "quality-aware sent {} to the 0.3x server, round-robin {}",
            qa_fleet[0].routed,
            rr_fleet[0].routed
        );
        true
    });
}
