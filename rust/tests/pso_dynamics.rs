//! PSO under dynamics (ROADMAP item, ISSUE 2): run `PsoAllocator` on
//! every epoch of a dynamic trace — with the swarm warm-started from
//! the previous epoch — and check it never loses to the equal-split
//! baseline.
//!
//! Why the strict comparison is sound at this load: with the paper's
//! deadlines (7–20 s) and the default 2 s plan horizon, every epoch
//! solve sees horizon-clamped budgets, so both runs partition arrivals
//! into identical epochs and serve every request; within one epoch the
//! swarm's particle 0 *is* the equal split, so the PSO pick can only
//! match or improve the epoch's mean quality.

use aigc_edge::bandwidth::{Allocator, EqualAllocator, PsoAllocator, PsoConfig};
use aigc_edge::config::{ArrivalProcessKind, ArrivalSettings, ExperimentConfig, ScenarioConfig};
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::quality::PowerLawQuality;
use aigc_edge::scheduler::Stacking;
use aigc_edge::sim::{simulate_dynamic, DynamicConfig, DynamicReport};
use aigc_edge::trace::ArrivalTrace;

fn trace(scenario: &ScenarioConfig, rate: f64, horizon: f64, seed: u64) -> ArrivalTrace {
    let arrival = ArrivalSettings {
        process: ArrivalProcessKind::Poisson,
        rate_hz: rate,
        burst_rate_hz: rate,
        period_s: 60.0,
        duty: 0.5,
        horizon_s: horizon,
        max_requests: 0,
        prompt_universe: 1,
        zipf_s: 1.0,
        models: 1,
    };
    ArrivalTrace::generate(scenario, &arrival, seed)
}

fn warm_pso() -> PsoAllocator {
    PsoAllocator::new(PsoConfig {
        particles: 8,
        iterations: 10,
        patience: 5,
        warm_start: true,
        ..Default::default()
    })
}

fn run(trace: &ArrivalTrace, allocator: &dyn Allocator) -> DynamicReport {
    simulate_dynamic(
        trace,
        &Stacking::default(),
        allocator,
        &BatchDelayModel::paper(),
        &PowerLawQuality::paper(),
        &DynamicConfig::default(),
    )
}

#[test]
fn pso_per_epoch_never_loses_to_equal_and_warm_starts() {
    let cfg = ExperimentConfig::paper();
    let t = trace(&cfg.scenario, 1.5, 40.0, 21);
    assert!(t.len() > 30, "trace too small to exercise multiple epochs");

    let equal = run(&t, &EqualAllocator);
    let pso_alloc = warm_pso();
    let pso = run(&t, &pso_alloc);

    assert_eq!(pso.outcomes.len(), equal.outcomes.len());
    assert_eq!(pso.dropped(), 0, "light load must serve everyone");
    assert_eq!(equal.dropped(), 0);
    assert!(
        pso.mean_quality() <= equal.mean_quality() + 1e-9,
        "per-epoch PSO (mean FID {:.4}) must not lose to equal split ({:.4})",
        pso.mean_quality(),
        equal.mean_quality()
    );
    // the swarm resumed from the previous epoch on every re-solve
    assert!(
        pso_alloc.warm_starts() >= 10,
        "expected warm starts across epochs, got {}",
        pso_alloc.warm_starts()
    );
    assert!(pso_alloc.warm_starts() < pso.epochs.len(), "first epoch starts cold");
}

#[test]
fn warm_started_runs_replay_bit_identically_with_fresh_allocators() {
    let cfg = ExperimentConfig::paper();
    let t = trace(&cfg.scenario, 2.0, 30.0, 5);
    // Warm starting is stateful across epochs *within* a run; replaying
    // the run with a fresh allocator must reproduce it exactly.
    let a = run(&t, &warm_pso());
    let b = run(&t, &warm_pso());
    assert_eq!(a.outcomes.len(), b.outcomes.len());
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.disposition, y.disposition);
        assert_eq!(x.steps, y.steps);
        assert_eq!(x.quality.to_bits(), y.quality.to_bits());
        assert_eq!(x.e2e_s.to_bits(), y.e2e_s.to_bits());
    }
    assert_eq!(a.horizon_s.to_bits(), b.horizon_s.to_bits());
}

#[test]
fn pso_stays_competitive_when_bandwidth_is_scarce() {
    // Tight band + tight deadlines: allocation actually matters, but
    // serving patterns may diverge across epochs (budgets are no longer
    // all horizon-clamped), so the comparison gets a small relative
    // slack instead of strict per-epoch dominance.
    let mut cfg = ExperimentConfig::paper();
    cfg.scenario.total_bandwidth_hz = 15_000.0;
    cfg.scenario.deadline_lo = 3.0;
    let t = trace(&cfg.scenario, 1.0, 30.0, 13);
    let equal = run(&t, &EqualAllocator);
    let pso = run(&t, &warm_pso());
    assert!(
        pso.mean_quality() <= equal.mean_quality() * 1.05 + 1e-9,
        "scarce-band PSO (mean FID {:.4}) should track or beat equal split ({:.4})",
        pso.mean_quality(),
        equal.mean_quality()
    );
}
