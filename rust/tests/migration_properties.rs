//! Property suite for the fault-aware cluster engine (ISSUE 3):
//! randomized traces, fleets, fault scripts and migration policies
//! through `sim::event`, asserting the conservation invariants
//! migration must never break.
//!
//! Invariants (each over ≥ 200 randomized runs):
//! * **conservation** — every arrival resolves exactly once, on at
//!   most one server, whatever dies mid-trace;
//! * **identity preservation** — a migrated request keeps its original
//!   arrival id, arrival instant and deadline, and its delays are
//!   charged from the *original* arrival (elapsed budget preserved);
//! * **determinism** — identical seeds (trace + fleet + faults +
//!   policy) replay bit-identically;
//! * **zero-fault degeneration** — an empty script with no migration
//!   reproduces `simulate_cluster` fleet stats bit-for-bit;
//! * **checkpoint conservation** — a resumed request keeps its
//!   identity and deadline, its salvaged steps never exceed the steps
//!   it is charged for, and with no faults `CheckpointOnDeath` is
//!   bit-identical to no migration at any transfer cost.

use aigc_edge::bandwidth::EqualAllocator;
use aigc_edge::config::{ArrivalProcessKind, ArrivalSettings, ExperimentConfig};
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::faults::{FaultScript, MigrationPolicyKind};
use aigc_edge::prop_assert;
use aigc_edge::quality::PowerLawQuality;
use aigc_edge::routing::RouterKind;
use aigc_edge::scheduler::Stacking;
use aigc_edge::sim::{
    simulate_cluster, simulate_event_cluster, ClusterConfig, Disposition, DynamicConfig,
    EventClusterConfig, EventReport, UNROUTED,
};
use aigc_edge::trace::ArrivalTrace;
use aigc_edge::util::prop::{forall, Gen};

/// A random small trace: Poisson or burst, a handful of seconds long.
fn random_trace(g: &mut Gen) -> ArrivalTrace {
    let mut scenario = ExperimentConfig::paper().scenario;
    scenario.deadline_lo = g.f64_in(1.0, 6.0);
    scenario.deadline_hi = scenario.deadline_lo + g.f64_in(1.0, 12.0);
    let burst = g.bool();
    let rate = g.f64_in(0.5, 8.0);
    let arrival = ArrivalSettings {
        process: if burst { ArrivalProcessKind::Burst } else { ArrivalProcessKind::Poisson },
        rate_hz: rate,
        burst_rate_hz: rate * g.f64_in(1.0, 3.0),
        period_s: g.f64_in(2.0, 15.0),
        duty: g.f64_in(0.1, 1.0),
        horizon_s: g.f64_in(3.0, 12.0),
        max_requests: 0,
        prompt_universe: 1,
        zipf_s: 1.0,
        models: 1,
    };
    ArrivalTrace::generate(&scenario, &arrival, g.u64())
}

/// A random fault script over the trace span (sometimes empty).
fn random_faults(g: &mut Gen, servers: usize, horizon_s: f64) -> FaultScript {
    if g.f64_in(0.0, 1.0) < 0.15 {
        return FaultScript::empty();
    }
    let mtbf = g.f64_in(2.0, 30.0);
    let mttr = g.f64_in(0.5, 10.0);
    FaultScript::random(servers, horizon_s * 1.2, mtbf, mttr, g.u64())
}

/// A random fleet's owned inputs; the (borrowing) `EventClusterConfig`
/// is assembled at the call site.
struct RandomFleet {
    speeds: Vec<f64>,
    router: RouterKind,
    migration: MigrationPolicyKind,
    transfer_s: f64,
}

fn random_fleet(g: &mut Gen) -> RandomFleet {
    let n = g.usize_in(1, 5);
    let speeds = g.vec_of(n, |g| g.f64_in(0.3, 2.5));
    let router = *g.pick(&RouterKind::all());
    let migration = *g.pick(&MigrationPolicyKind::all());
    let transfer_s = g.f64_in(0.0, 1.5);
    RandomFleet { speeds, router, migration, transfer_s }
}

/// Drop script intervals naming servers outside the fleet.
fn clamp_to_fleet(faults: &FaultScript, servers: usize) -> FaultScript {
    FaultScript::scheduled(
        faults.downs().iter().copied().filter(|d| d.server < servers).collect(),
    )
    .unwrap()
}

fn run(trace: &ArrivalTrace, cfg: &EventClusterConfig) -> EventReport {
    simulate_event_cluster(
        trace,
        &Stacking::default(),
        &EqualAllocator,
        &BatchDelayModel::paper(),
        &PowerLawQuality::paper(),
        cfg,
    )
}

#[test]
fn no_request_lost_or_double_served_across_failures() {
    forall("fault conservation", 200, |g: &mut Gen| {
        let trace = random_trace(g);
        let faults = random_faults(g, 5, trace.duration_s());
        let fleet = random_fleet(g);
        // the script may name servers the fleet doesn't have; clamp it
        let faults = clamp_to_fleet(&faults, fleet.speeds.len());
        let cfg = EventClusterConfig {
            speeds: &fleet.speeds,
            router: fleet.router,
            dynamic: DynamicConfig::default(),
            faults: &faults,
            migration: fleet.migration,
            resume_transfer_s: fleet.transfer_s,
        };
        let report = run(&trace, &cfg);
        prop_assert!(g, report.outcomes.len() == trace.len(), "outcome count");
        prop_assert!(
            g,
            report.served() + report.dropped() == trace.len(),
            "served {} + dropped {} != {}",
            report.served(),
            report.dropped(),
            trace.len()
        );
        // every id resolved exactly once, and by at most one server;
        // death-retracted slots are tombstoned in place inside the
        // engine and must never escape into the report
        let mut counts = vec![0usize; trace.len()];
        for s in &report.servers {
            for &id in &s.resolved_ids {
                prop_assert!(g, id < trace.len(), "tombstone leaked into resolved_ids: {id}");
                counts[id] += 1;
            }
        }
        for (id, o) in report.outcomes.iter().enumerate() {
            prop_assert!(g, o.id == id, "outcome {id} holds id {}", o.id);
            prop_assert!(g, counts[id] <= 1, "request {id} resolved by {} servers", counts[id]);
            // a request no server resolved can only be a fleet-wide
            // outage loss (parked unroutable until it expired)
            if counts[id] == 0 {
                prop_assert!(g, o.disposition == Disposition::LostToFailure, "request {id}");
            }
            // never dispatched anywhere => lost to a fleet-wide outage
            if report.assignment[id] == UNROUTED {
                prop_assert!(g, o.disposition == Disposition::LostToFailure, "unrouted {id}");
            }
        }
        true
    });
}

#[test]
fn migrated_requests_keep_identity_and_budget() {
    forall("migration identity", 200, |g: &mut Gen| {
        let trace = random_trace(g);
        let n = g.usize_in(2, 4);
        let speeds = g.vec_of(n, |g| g.f64_in(0.4, 2.0));
        let (mtbf, mttr) = (g.f64_in(2.0, 15.0), g.f64_in(0.5, 6.0));
        let faults = FaultScript::random(n, trace.duration_s() * 1.2, mtbf, mttr, g.u64());
        let cfg = EventClusterConfig {
            speeds: &speeds,
            router: *g.pick(&RouterKind::all()),
            dynamic: DynamicConfig::default(),
            faults: &faults,
            migration: MigrationPolicyKind::RequeueOnDeath,
            resume_transfer_s: 0.0,
        };
        let report = run(&trace, &cfg);
        for m in &report.migrations {
            prop_assert!(g, m.id < trace.len(), "migration names request {}", m.id);
            let o = &report.outcomes[m.id];
            let a = &trace.arrivals[m.id];
            prop_assert!(g, o.id == m.id, "id preserved");
            prop_assert!(g, o.arrival_s.to_bits() == a.t_s.to_bits(), "arrival preserved");
            prop_assert!(g, o.deadline_s.to_bits() == a.deadline_s.to_bits(), "deadline preserved");
            // the hand-off instant respects causality
            prop_assert!(g, m.t_s >= a.t_s - 1e-12, "migrated before arriving");
            if let Some(to) = m.to {
                prop_assert!(g, to < cfg.servers(), "target in fleet");
            }
        }
        // delays are charged from the original arrival: a served
        // request's e2e spans arrival -> resolution exactly
        for o in &report.outcomes {
            if o.disposition.is_served() {
                let span = o.resolved_s - o.arrival_s;
                prop_assert!(g, (span - o.e2e_s).abs() < 1e-9, "e2e {} vs span {span}", o.e2e_s);
            }
        }
        true
    });
}

#[test]
fn checkpointed_resumes_conserve_steps_and_identity() {
    forall("checkpoint conservation", 200, |g: &mut Gen| {
        let trace = random_trace(g);
        let n = g.usize_in(2, 5);
        let speeds = g.vec_of(n, |g| g.f64_in(0.4, 2.0));
        let (mtbf, mttr) = (g.f64_in(2.0, 15.0), g.f64_in(0.5, 6.0));
        let faults = FaultScript::random(n, trace.duration_s() * 1.2, mtbf, mttr, g.u64());
        let cfg = EventClusterConfig {
            speeds: &speeds,
            router: *g.pick(&RouterKind::all()),
            dynamic: DynamicConfig::default(),
            faults: &faults,
            migration: MigrationPolicyKind::Checkpoint,
            resume_transfer_s: g.f64_in(0.0, 1.5),
        };
        let report = run(&trace, &cfg);
        // conservation still holds with resumes in the mix
        prop_assert!(
            g,
            report.served() + report.dropped() == trace.len(),
            "served {} + dropped {} != {}",
            report.served(),
            report.dropped(),
            trace.len()
        );
        for o in &report.outcomes {
            let a = &trace.arrivals[o.id];
            if o.disposition == Disposition::ResumedElsewhere {
                // a resume only exists when the checkpoint saved work
                prop_assert!(g, o.recovered_steps > 0, "resume {} salvaged nothing", o.id);
                // identity and deadline survive the hand-off
                prop_assert!(g, o.arrival_s.to_bits() == a.t_s.to_bits(), "arrival {}", o.id);
                prop_assert!(
                    g,
                    o.deadline_s.to_bits() == a.deadline_s.to_bits(),
                    "deadline {}",
                    o.id
                );
                // a resume flagged as met honours the *original*
                // absolute deadline, not one restarted at the hand-off
                if o.met {
                    prop_assert!(
                        g,
                        o.resolved_s <= a.t_s + a.deadline_s + 1e-9,
                        "resume {} resolved {} past deadline {}",
                        o.id,
                        o.resolved_s,
                        a.t_s + a.deadline_s
                    );
                }
            } else {
                // only resumes carry salvaged steps
                prop_assert!(g, o.recovered_steps == 0, "non-resume {} recovered", o.id);
            }
            // charged steps always include the salvaged prefix
            prop_assert!(
                g,
                o.steps >= o.recovered_steps,
                "request {}: steps {} < recovered {}",
                o.id,
                o.steps,
                o.recovered_steps
            );
            if o.disposition.is_served() {
                let span = o.resolved_s - o.arrival_s;
                prop_assert!(g, (span - o.e2e_s).abs() < 1e-9, "e2e {} vs span {span}", o.e2e_s);
            }
        }
        true
    });
}

#[test]
fn non_checkpoint_policies_never_resume() {
    forall("no phantom resumes", 100, |g: &mut Gen| {
        let trace = random_trace(g);
        let faults = random_faults(g, 5, trace.duration_s());
        let mut fleet = random_fleet(g);
        if fleet.migration == MigrationPolicyKind::Checkpoint {
            fleet.migration = MigrationPolicyKind::RequeueOnDeath;
        }
        let faults = clamp_to_fleet(&faults, fleet.speeds.len());
        let cfg = EventClusterConfig {
            speeds: &fleet.speeds,
            router: fleet.router,
            dynamic: DynamicConfig::default(),
            faults: &faults,
            migration: fleet.migration,
            resume_transfer_s: fleet.transfer_s,
        };
        let report = run(&trace, &cfg);
        prop_assert!(g, report.resumed_elsewhere() == 0, "{:?} resumed", fleet.migration);
        prop_assert!(g, report.recovered_steps() == 0, "{:?} salvaged", fleet.migration);
        true
    });
}

#[test]
fn zero_fault_checkpoint_matches_none_bitwise() {
    forall("checkpoint zero-fault degeneration", 60, |g: &mut Gen| {
        let trace = random_trace(g);
        let n = g.usize_in(1, 4);
        let speeds = g.vec_of(n, |g| g.f64_in(0.4, 2.0));
        let router = *g.pick(&RouterKind::all());
        let empty = FaultScript::empty();
        let mk = |migration, transfer_s| EventClusterConfig {
            speeds: &speeds,
            router,
            dynamic: DynamicConfig::default(),
            faults: &empty,
            migration,
            resume_transfer_s: transfer_s,
        };
        let none = run(&trace, &mk(MigrationPolicyKind::None, 0.0));
        let ckpt = run(&trace, &mk(MigrationPolicyKind::Checkpoint, g.f64_in(0.0, 2.0)));
        prop_assert!(g, none.assignment == ckpt.assignment, "assignment");
        prop_assert!(g, ckpt.resumed_elsewhere() == 0, "fault-free resumes");
        for (x, y) in none.outcomes.iter().zip(&ckpt.outcomes) {
            prop_assert!(g, x.disposition == y.disposition, "disposition {}", x.id);
            prop_assert!(g, x.steps == y.steps, "steps {}", x.id);
            prop_assert!(g, x.quality.to_bits() == y.quality.to_bits(), "quality {}", x.id);
            prop_assert!(
                g,
                x.resolved_s.to_bits() == y.resolved_s.to_bits(),
                "resolution {}",
                x.id
            );
        }
        prop_assert!(g, none.horizon_s.to_bits() == ckpt.horizon_s.to_bits(), "horizon");
        true
    });
}

#[test]
fn replay_is_seed_identical_under_faults() {
    forall("fault replay", 60, |g: &mut Gen| {
        let trace = random_trace(g);
        let faults = random_faults(g, 3, trace.duration_s());
        let fleet = random_fleet(g);
        let faults = clamp_to_fleet(&faults, fleet.speeds.len());
        let cfg = EventClusterConfig {
            speeds: &fleet.speeds,
            router: fleet.router,
            dynamic: DynamicConfig::default(),
            faults: &faults,
            migration: fleet.migration,
            resume_transfer_s: fleet.transfer_s,
        };
        let a = run(&trace, &cfg);
        let b = run(&trace, &cfg);
        prop_assert!(g, a.assignment == b.assignment, "assignment replay");
        prop_assert!(g, a.migrations.len() == b.migrations.len(), "migration replay");
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            prop_assert!(g, x.disposition == y.disposition, "disposition replay {}", x.id);
            prop_assert!(g, x.quality.to_bits() == y.quality.to_bits(), "quality replay {}", x.id);
            prop_assert!(
                g,
                x.resolved_s.to_bits() == y.resolved_s.to_bits(),
                "resolution replay {}",
                x.id
            );
        }
        prop_assert!(g, a.horizon_s.to_bits() == b.horizon_s.to_bits(), "horizon replay");
        true
    });
}

#[test]
fn zero_fault_none_policy_degenerates_to_simulate_cluster() {
    forall("zero-fault degeneration", 60, |g: &mut Gen| {
        let trace = random_trace(g);
        let n = g.usize_in(1, 4);
        let cluster = ClusterConfig {
            speeds: g.vec_of(n, |g| g.f64_in(0.4, 2.0)),
            router: *g.pick(&RouterKind::all()),
            dynamic: DynamicConfig::default(),
        };
        let seq = simulate_cluster(
            &trace,
            &Stacking::default(),
            &EqualAllocator,
            &BatchDelayModel::paper(),
            &PowerLawQuality::paper(),
            &cluster,
        );
        let ev = run(&trace, &EventClusterConfig::fault_free(&cluster));
        let (s, e) = (seq.fleet_stats(), ev.fleet_stats());
        prop_assert!(g, s.count == e.count, "count");
        prop_assert!(g, s.served == e.served, "served");
        prop_assert!(g, s.mean_quality.to_bits() == e.mean_quality.to_bits(), "quality");
        prop_assert!(g, s.outage_rate.to_bits() == e.outage_rate.to_bits(), "outage");
        prop_assert!(g, s.p99_e2e_s.to_bits() == e.p99_e2e_s.to_bits(), "p99");
        prop_assert!(g, ev.assignment == seq.assignment, "assignment");
        true
    });
}
