//! Thread-count invariance of the parallel solve fabric (ISSUE 5):
//! every output — PSO allocations, cluster/event epoch traces, full
//! bench sweeps — is **bitwise identical** at threads ∈ {1, 2, 8}.
//!
//! This is the property that makes `threads` a pure performance knob:
//! `util::exec::par_map` preserves order, PSO's synchronous update is
//! evaluation-order-free, and the engines only fan out solves that
//! cannot observe each other. Seeded workloads, warm start on and off,
//! faults on and off.

use aigc_edge::bandwidth::{Allocator, AllocatorPool, EqualAllocator, PsoAllocator, PsoConfig};
use aigc_edge::config::{ArrivalProcessKind, ArrivalSettings, ExperimentConfig};
use aigc_edge::coordinator::SolveMode;
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::faults::{DownInterval, FaultScript, MigrationPolicyKind, NO_FAULTS};
use aigc_edge::quality::PowerLawQuality;
use aigc_edge::routing::RouterKind;
use aigc_edge::scheduler::Stacking;
use aigc_edge::sim::{
    server_speeds, simulate_cluster, simulate_event_cluster, simulate_event_cluster_pooled,
    solve_joint, ClusterConfig, DynamicConfig, EventClusterConfig, RequestOutcome,
};
use aigc_edge::trace::{generate, ArrivalTrace};

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

fn trace(rate: f64, horizon: f64, seed: u64) -> ArrivalTrace {
    let cfg = ExperimentConfig::paper();
    let arrival = ArrivalSettings {
        process: ArrivalProcessKind::Poisson,
        rate_hz: rate,
        burst_rate_hz: rate,
        period_s: 60.0,
        duty: 0.5,
        horizon_s: horizon,
        max_requests: 0,
        prompt_universe: 1,
        zipf_s: 1.0,
        models: 1,
    };
    ArrivalTrace::generate(&cfg.scenario, &arrival, seed)
}

fn outcome_bits(outcomes: &[RequestOutcome]) -> Vec<u64> {
    let mut out = Vec::with_capacity(outcomes.len() * 5);
    for o in outcomes {
        out.push(o.steps as u64);
        out.push(o.deferrals as u64 ^ ((o.epoch as u64) << 32));
        out.push(o.quality.to_bits());
        out.push(o.e2e_s.to_bits());
        out.push(o.resolved_s.to_bits());
    }
    out
}

#[test]
fn pso_allocations_bitwise_identical_across_thread_counts() {
    let quality = PowerLawQuality::paper();
    let delay = BatchDelayModel::paper();
    let scheduler = Stacking::default();
    for seed in [3u64, 7] {
        let workload = generate(&ExperimentConfig::paper().scenario, seed);
        for warm_start in [false, true] {
            let solve_twice = |threads: usize| -> (Vec<u64>, Vec<u64>) {
                let pso = PsoAllocator::new(PsoConfig {
                    particles: 10,
                    iterations: 12,
                    patience: 6,
                    warm_start,
                    threads,
                    ..Default::default()
                });
                // two solves: the second exercises warm start (when on)
                // and scratch reuse (always)
                let a = solve_joint(&workload, &scheduler, &pso, &delay, &quality);
                let b = solve_joint(&workload, &scheduler, &pso, &delay, &quality);
                let bits = |s: &aigc_edge::sim::JointSolution| -> Vec<u64> {
                    s.outcome.allocation_hz.iter().map(|x| x.to_bits()).collect()
                };
                (bits(&a), bits(&b))
            };
            let reference = solve_twice(1);
            for threads in THREAD_COUNTS {
                let got = solve_twice(threads);
                assert_eq!(
                    got, reference,
                    "seed {seed} warm={warm_start} threads={threads}: PSO diverged"
                );
            }
        }
    }
}

#[test]
fn cluster_epoch_traces_identical_across_thread_counts() {
    let t = trace(6.0, 40.0, 7);
    let quality = PowerLawQuality::paper();
    let delay = BatchDelayModel::paper();
    let scheduler = Stacking::default();
    for router in RouterKind::all() {
        let run = |threads: usize| {
            let mut dynamic = DynamicConfig::default();
            dynamic.threads = threads;
            let cfg = ClusterConfig { speeds: server_speeds(3, 0.5, 1.5), router, dynamic };
            simulate_cluster(&t, &scheduler, &EqualAllocator, &delay, &quality, &cfg)
        };
        let reference = run(1);
        for threads in THREAD_COUNTS {
            let got = run(threads);
            let tag = format!("{} threads={threads}", router.name());
            assert_eq!(got.assignment, reference.assignment, "{tag}");
            assert_eq!(outcome_bits(&got.outcomes), outcome_bits(&reference.outcomes), "{tag}");
            assert_eq!(got.horizon_s.to_bits(), reference.horizon_s.to_bits(), "{tag}");
            for (a, b) in got.servers.iter().zip(&reference.servers) {
                assert_eq!(a.report.epochs.len(), b.report.epochs.len(), "{tag}");
                for (x, y) in a.report.epochs.iter().zip(&b.report.epochs) {
                    assert_eq!(x.t_solve_s.to_bits(), y.t_solve_s.to_bits(), "{tag}");
                    assert_eq!(x.served, y.served, "{tag}");
                    assert_eq!(x.makespan_s.to_bits(), y.makespan_s.to_bits(), "{tag}");
                }
            }
        }
    }
}

#[test]
fn event_engine_identical_across_thread_counts_faults_on_and_off() {
    let t = trace(5.0, 40.0, 11);
    let quality = PowerLawQuality::paper();
    let delay = BatchDelayModel::paper();
    let scheduler = Stacking::default();
    let speeds = server_speeds(3, 0.5, 1.5);
    let faulty = FaultScript::random(3, 50.0, 20.0, 6.0, 13);
    let scripts: [(&str, &FaultScript, MigrationPolicyKind); 3] = [
        ("no-faults", &NO_FAULTS, MigrationPolicyKind::None),
        ("faults-requeue", &faulty, MigrationPolicyKind::RequeueOnDeath),
        ("faults-steal", &faulty, MigrationPolicyKind::StealWhenIdle),
    ];
    for (name, faults, migration) in scripts {
        let lifecycles = [
            (SolveMode::Pipelined, 0.0),
            (SolveMode::Pipelined, 0.2),
            (SolveMode::Synchronous, 0.2),
        ];
        for (mode, latency) in lifecycles {
            let run = |threads: usize| {
                let mut dynamic = DynamicConfig::default();
                dynamic.solve_mode = mode;
                dynamic.solve_latency_s = latency;
                dynamic.threads = threads;
                let cfg = EventClusterConfig {
                    speeds: &speeds,
                    router: RouterKind::JoinShortestQueue,
                    dynamic,
                    faults,
                    migration,
                    resume_transfer_s: 0.1,
                };
                simulate_event_cluster(&t, &scheduler, &EqualAllocator, &delay, &quality, &cfg)
            };
            let reference = run(1);
            for threads in THREAD_COUNTS {
                let got = run(threads);
                let tag = format!("{name} {} L={latency} threads={threads}", mode.name());
                assert_eq!(got.assignment, reference.assignment, "{tag}");
                assert_eq!(
                    outcome_bits(&got.outcomes),
                    outcome_bits(&reference.outcomes),
                    "{tag}"
                );
                assert_eq!(got.migrations.len(), reference.migrations.len(), "{tag}");
                assert_eq!(got.horizon_s.to_bits(), reference.horizon_s.to_bits(), "{tag}");
            }
        }
    }
}

/// The event engine's main loop picks its next server event from a
/// lazily-invalidated min-heap instead of rescanning every server per
/// step. Tie instants are where that structure could bite — epoch
/// closes aligned across servers, faults scheduled exactly on those
/// boundaries — so hammer a tie-heavy script under every router ×
/// migration policy and require bit-identical replay plus census
/// conservation.
#[test]
fn event_heap_schedule_replays_bitwise_under_tie_heavy_scripts() {
    let t = trace(8.0, 30.0, 17);
    let quality = PowerLawQuality::paper();
    let delay = BatchDelayModel::paper();
    let scheduler = Stacking::default();
    let speeds = server_speeds(4, 0.5, 2.0);
    // Default epochs close on the integer grid; these down intervals
    // start and end exactly there, so fault, resume, and server events
    // repeatedly share an instant and only the fault < resume <
    // arrival < server (then lowest server id) tie order separates
    // them.
    let script = FaultScript::scheduled(vec![
        DownInterval::new(1, 5.0, 9.0).unwrap(),
        DownInterval::new(2, 5.0, 12.0).unwrap(),
        DownInterval::new(3, 10.0, 11.0).unwrap(),
    ])
    .unwrap();
    let routers = [
        RouterKind::JoinShortestQueue,
        RouterKind::QualityAware,
        RouterKind::LiveState,
        RouterKind::CacheAware,
    ];
    for router in routers {
        for migration in MigrationPolicyKind::all() {
            let run = || {
                let cfg = EventClusterConfig {
                    speeds: &speeds,
                    router,
                    dynamic: DynamicConfig::default(),
                    faults: &script,
                    migration,
                    resume_transfer_s: 0.2,
                };
                simulate_event_cluster(&t, &scheduler, &EqualAllocator, &delay, &quality, &cfg)
            };
            let a = run();
            let b = run();
            let tag = format!("{} {}", router.name(), migration.name());
            assert_eq!(a.served() + a.dropped(), t.len(), "{tag}: census leak");
            assert_eq!(a.assignment, b.assignment, "{tag}");
            assert_eq!(outcome_bits(&a.outcomes), outcome_bits(&b.outcomes), "{tag}");
            assert_eq!(a.migrations.len(), b.migrations.len(), "{tag}");
            assert_eq!(a.horizon_s.to_bits(), b.horizon_s.to_bits(), "{tag}");
        }
    }
}

/// Per-server warm-start pools are pairwise-distinct instances, so the
/// engines may fan their solves out — and must still replay exactly.
#[test]
fn pooled_warm_start_event_runs_identical_across_thread_counts() {
    let t = trace(6.0, 30.0, 5);
    let quality = PowerLawQuality::paper();
    let delay = BatchDelayModel::paper();
    let scheduler = Stacking::default();
    let speeds = server_speeds(3, 0.6, 1.6);
    let run = |threads: usize| {
        let pool = AllocatorPool::per_server(3, |_| {
            Box::new(PsoAllocator::new(PsoConfig {
                particles: 6,
                iterations: 6,
                patience: 3,
                warm_start: true,
                ..Default::default()
            })) as Box<dyn Allocator>
        });
        let mut dynamic = DynamicConfig::default();
        dynamic.threads = threads;
        let cfg = EventClusterConfig {
            speeds: &speeds,
            router: RouterKind::JoinShortestQueue,
            dynamic,
            faults: &NO_FAULTS,
            migration: MigrationPolicyKind::None,
            resume_transfer_s: 0.0,
        };
        simulate_event_cluster_pooled(&t, &scheduler, &pool, &delay, &quality, &cfg)
    };
    let reference = run(1);
    for threads in THREAD_COUNTS {
        let got = run(threads);
        assert_eq!(got.assignment, reference.assignment, "threads={threads}");
        assert_eq!(
            outcome_bits(&got.outcomes),
            outcome_bits(&reference.outcomes),
            "threads={threads}"
        );
    }
}

/// Full sweep outputs (the bench layer's fan-out) replay identically:
/// `FigClusterRow`/`FigPipelineRow` derive `PartialEq`, so row-for-row
/// equality covers every published number.
#[test]
fn bench_sweeps_identical_across_thread_counts() {
    let mut cfg = ExperimentConfig::paper();
    cfg.cluster.servers = 2;
    cfg.cluster.speed_min = 0.6;
    cfg.cluster.speed_max = 1.4;
    cfg.arrival.rate_hz = 3.0;
    cfg.arrival.burst_rate_hz = 9.0;
    cfg.perf.threads = 1;
    let cluster_ref = aigc_edge::bench::fig_cluster(&cfg, &[1.0, 4.0], 20.0);
    let pipeline_ref = aigc_edge::bench::fig_pipeline(&cfg, &[0.0, 0.2], 20.0);
    let faults_ref = aigc_edge::bench::fig_faults(&cfg, &[0.0, 2.0], 20.0);
    let cache_ref = aigc_edge::bench::fig_cache(&cfg, &[1.5], &[16], 20.0);
    for threads in [2usize, 8] {
        cfg.perf.threads = threads;
        assert_eq!(
            aigc_edge::bench::fig_cluster(&cfg, &[1.0, 4.0], 20.0),
            cluster_ref,
            "fig_cluster threads={threads}"
        );
        assert_eq!(
            aigc_edge::bench::fig_pipeline(&cfg, &[0.0, 0.2], 20.0),
            pipeline_ref,
            "fig_pipeline threads={threads}"
        );
        assert_eq!(
            aigc_edge::bench::fig_faults(&cfg, &[0.0, 2.0], 20.0),
            faults_ref,
            "fig_faults threads={threads}"
        );
        assert_eq!(
            aigc_edge::bench::fig_cache(&cfg, &[1.5], &[16], 20.0),
            cache_ref,
            "fig_cache threads={threads}"
        );
    }
}
