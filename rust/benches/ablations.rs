//! Ablations over the design choices DESIGN.md calls out (not in the
//! paper): the T* search, PSO budget, fixed batch sizes, and the
//! bucket-granularity of the compiled artifacts.

use aigc_edge::bandwidth::{EqualAllocator, PsoAllocator, PsoConfig};
use aigc_edge::bench::TableWriter;
use aigc_edge::config::ExperimentConfig;
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::quality::PowerLawQuality;
use aigc_edge::scheduler::{BatchScheduler, FixedSizeBatching, Stacking, StackingConfig};
use aigc_edge::sim::solve_joint;
use aigc_edge::trace::generate;

fn main() {
    let cfg = ExperimentConfig::paper();
    let delay = BatchDelayModel::paper();
    let quality = PowerLawQuality::paper();
    let reps = std::env::var("BENCH_REPS").ok().and_then(|s| s.parse().ok()).unwrap_or(3);

    // ---- A1: T* search cap ----
    // STACKING's quality as the T* grid is truncated: a tiny grid can't
    // balance step counts; past the feasible maximum extra grid is waste.
    let mut t1 =
        TableWriter::new("A1 — STACKING T* search cap", &["t_star_max", "mean FID", "solve ms"])
            .with_csv("ablation_tstar");
    let mut prev_q = f64::INFINITY;
    for cap in [1u32, 2, 4, 8, 16, 32, 64] {
        let sched = Stacking::new(StackingConfig {
            t_star_max: Some(cap),
            max_steps: 1000,
            ..Default::default()
        });
        let mut acc = 0.0;
        let t0 = std::time::Instant::now();
        for seed in 0..reps {
            let w = generate(&cfg.scenario, cfg.seed + seed as u64);
            acc +=
                solve_joint(&w, &sched, &EqualAllocator, &delay, &quality).outcome.mean_quality();
        }
        let q = acc / reps as f64;
        let ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
        t1.row(&[cap.to_string(), format!("{q:.3}"), format!("{ms:.1}")]);
        if cap >= 32 {
            assert!(q <= prev_q + 0.5, "larger T* grid should not hurt");
        }
        prev_q = q;
    }
    t1.finish();

    // ---- A2: PSO budget ----
    let mut t2 = TableWriter::new(
        "A2 — PSO budget (particles x iterations)",
        &["particles", "iters", "mean FID", "inner evals"],
    )
    .with_csv("ablation_pso");
    for (p, it) in [(4, 6), (8, 12), (16, 24), (24, 40)] {
        let alloc = PsoAllocator::new(PsoConfig {
            particles: p,
            iterations: it,
            patience: 0,
            ..Default::default()
        });
        let mut acc = 0.0;
        let mut evals = 0usize;
        for seed in 0..reps {
            let w = generate(&cfg.scenario, cfg.seed + seed as u64);
            let sol = solve_joint(&w, &Stacking::default(), &alloc, &delay, &quality);
            acc += sol.outcome.mean_quality();
            evals += sol.inner_evals;
        }
        t2.row(&[
            p.to_string(),
            it.to_string(),
            format!("{:.3}", acc / reps as f64),
            (evals / reps).to_string(),
        ]);
    }
    t2.finish();

    // ---- A3: fixed batch size sweep (why ⌊K/2⌋ isn't enough) ----
    let mut t3 = TableWriter::new("A3 — fixed batch size", &["batch", "mean FID"])
        .with_csv("ablation_fixed_size");
    let mut fixed_results = Vec::new();
    for size in [2u32, 5, 10, 15, 20] {
        let sched = FixedSizeBatching::new(size);
        let mut acc = 0.0;
        for seed in 0..reps {
            let w = generate(&cfg.scenario, cfg.seed + seed as u64);
            acc +=
                solve_joint(&w, &sched, &EqualAllocator, &delay, &quality).outcome.mean_quality();
        }
        fixed_results.push(acc / reps as f64);
        t3.row(&[size.to_string(), format!("{:.3}", acc / reps as f64)]);
    }
    t3.finish();
    // STACKING beats every fixed size
    let mut stacking_acc = 0.0;
    for seed in 0..reps {
        let w = generate(&cfg.scenario, cfg.seed + seed as u64);
        stacking_acc += solve_joint(&w, &Stacking::default(), &EqualAllocator, &delay, &quality)
            .outcome
            .mean_quality();
    }
    let stacking_q = stacking_acc / reps as f64;
    println!("STACKING (same allocator): {stacking_q:.3}");
    for (i, q) in fixed_results.iter().enumerate() {
        assert!(stacking_q <= q + 1e-9, "fixed size #{i} beat STACKING");
    }

    // ---- A4: delay-model regimes (b/a ratio) ----
    // The paper's insight needs b >> a; sweep the ratio to show when
    // batching stops paying.
    let mut t4 = TableWriter::new(
        "A4 — delay regime sweep g(X)=aX+b (stacking vs single-instance)",
        &["a", "b", "stacking FID", "single FID"],
    )
    .with_csv("ablation_delay_regime");
    for (a, b) in [(0.0240, 0.3543), (0.1, 0.1), (0.3, 0.01)] {
        let d = BatchDelayModel::new(a, b);
        let mut sq = 0.0;
        let mut gq = 0.0;
        for seed in 0..reps {
            let w = generate(&cfg.scenario, cfg.seed + seed as u64);
            sq += solve_joint(&w, &Stacking::default(), &EqualAllocator, &d, &quality)
                .outcome
                .mean_quality();
            gq += solve_joint(
                &w,
                &aigc_edge::scheduler::SingleInstance::default(),
                &EqualAllocator,
                &d,
                &quality,
            )
            .outcome
            .mean_quality();
        }
        t4.row(&[
            format!("{a}"),
            format!("{b}"),
            format!("{:.2}", sq / reps as f64),
            format!("{:.2}", gq / reps as f64),
        ]);
    }
    t4.finish();
    println!("\nablations OK");
}
