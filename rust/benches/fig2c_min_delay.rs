//! Fig. 2c — mean FID vs minimum delay requirement (τmax = 20 s, K = 20),
//! five schemes. BENCH_REPS controls seeds per point (default 3).

use aigc_edge::bench;
use aigc_edge::config::ExperimentConfig;

fn main() {
    let reps = std::env::var("BENCH_REPS").ok().and_then(|s| s.parse().ok()).unwrap_or(3);
    let mut cfg = ExperimentConfig::paper();
    cfg.pso.particles = 12;
    cfg.pso.iterations = 16;
    cfg.pso.patience = 8;
    let taus = [3.0, 5.0, 7.0, 9.0, 11.0, 13.0, 15.0, 17.0, 19.0];
    let rows = bench::fig2c(&cfg, &taus, reps);

    for (tau, vals) in &rows {
        for (i, v) in vals.iter().enumerate() {
            assert!(vals[0] <= v * 1.02 + 1e-9, "tau_min={tau}: scheme {i} beats proposed");
        }
    }
    // proposed improves as the minimum deadline loosens
    let proposed: Vec<f64> = rows.iter().map(|r| r.1[0]).collect();
    assert!(
        proposed.first().unwrap() > proposed.last().unwrap(),
        "quality should improve with looser deadlines: {proposed:?}"
    );
    // the PSO-vs-equal gap (index 4 is equal-bandwidth) is larger at
    // tighter tau_min
    let gap_tight = rows[0].1[4] - rows[0].1[0];
    let gap_loose = rows[rows.len() - 1].1[4] - rows[rows.len() - 1].1[0];
    assert!(
        gap_tight >= gap_loose - 0.5,
        "bandwidth-allocation gain should be largest under tight deadlines"
    );
    println!("\nfig2c OK");
}
