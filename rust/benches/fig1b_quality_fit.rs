//! Fig. 1b — FID-like quality vs denoising steps: the measured
//! calibration curve plus the power-law fit, paper vs rust re-fit.

use aigc_edge::bench;
use aigc_edge::config::ExperimentConfig;

fn main() {
    let cfg = ExperimentConfig::paper();
    let rows = bench::fig1b(&cfg);
    // Shape assertions: steep early gains, flat tail.
    let q1 = rows.first().unwrap().1;
    let mid = rows[rows.len() / 2].1;
    let qend = rows.last().unwrap().1;
    assert!(q1 > 2.0 * mid, "early steps must dominate quality gains");
    assert!(mid > qend, "curve must keep (slowly) improving");
    let early_gain = q1 - mid;
    let late_gain = mid - qend;
    assert!(early_gain > 3.0 * late_gain, "diminishing returns expected");
    println!("\nfig1b OK");
}
