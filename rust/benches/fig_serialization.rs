//! Trace-serialization shootout: columnar vs CSV vs JSON (the ROADMAP
//! "serialization on a bench" item), folded into `BENCH_pr8.json`.
//! (`harness = false`: criterion is not in the offline vendored set.)
//!
//! Properties asserted here:
//!  * every codec round-trips a large generated arrival trace
//!    *bit-identically* (same f64 bits per column, same scenario
//!    constants) — replayed simulations cannot drift;
//!  * the columnar format is the smallest of the three — it exists to
//!    beat the text codecs, so a regression here is a real bug;
//!  * encode/decode wall-clock and bytes-per-request are measured and
//!    reported for all three codecs (throughput is informational —
//!    shared CI wall-clock is noise, the sizes and round-trips gate).
//!
//! Run after `obs_overhead` (CI does): the results merge into the
//! existing `BENCH_pr8.json` under a `"serialization"` key via
//! `util::json` (parse → insert → render re-parses losslessly).

use std::path::Path;
use std::time::Instant;

use aigc_edge::channel::Link;
use aigc_edge::config::ExperimentConfig;
use aigc_edge::trace::{columnar, Arrival, ArrivalTrace, PromptMark};
use aigc_edge::util::json::{self, Json};

/// Columnar JSON codec for a trace (arrays per column). f64 `Display`
/// is shortest-round-trip, so the bits survive the text round-trip.
fn to_json(trace: &ArrivalTrace) -> String {
    let col = |f: &dyn Fn(&Arrival) -> f64| {
        let mut out = String::from("[");
        for (i, a) in trace.arrivals.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("{}", f(a)));
        }
        out.push(']');
        out
    };
    format!(
        "{{\"total_bandwidth_hz\":{},\"content_bits\":{},\"t_s\":{},\"deadline_s\":{},\"eta\":{}}}",
        trace.total_bandwidth_hz,
        trace.content_bits,
        col(&|a| a.t_s),
        col(&|a| a.deadline_s),
        col(&|a| a.link.spectral_efficiency),
    )
}

fn from_json(text: &str) -> ArrivalTrace {
    let v = json::parse(text).expect("trace JSON parses");
    let f = |k: &str| v.get(k).and_then(Json::as_f64).expect("scenario constant");
    let col = |k: &str| -> Vec<f64> {
        let arr = v.get(k).and_then(Json::as_arr).expect("column array");
        arr.iter().map(|x| x.as_f64().expect("column value")).collect()
    };
    let (t_s, deadline_s, eta) = (col("t_s"), col("deadline_s"), col("eta"));
    assert_eq!(t_s.len(), deadline_s.len());
    assert_eq!(t_s.len(), eta.len());
    let arrivals = t_s
        .iter()
        .zip(&deadline_s)
        .zip(&eta)
        .enumerate()
        .map(|(id, ((&t, &d), &e))| Arrival {
            id,
            t_s: t,
            deadline_s: d,
            link: Link::new(e),
            // This bench's JSON codec predates prompt marks; the bench
            // trace is unmarked, so zero round-trips faithfully.
            mark: PromptMark::ZERO,
        })
        .collect();
    ArrivalTrace {
        arrivals,
        total_bandwidth_hz: f("total_bandwidth_hz"),
        content_bits: f("content_bits"),
    }
}

fn assert_traces_bitwise(a: &ArrivalTrace, b: &ArrivalTrace, codec: &str) {
    assert_eq!(a.total_bandwidth_hz.to_bits(), b.total_bandwidth_hz.to_bits(), "{codec}");
    assert_eq!(a.content_bits.to_bits(), b.content_bits.to_bits(), "{codec}");
    assert_eq!(a.arrivals.len(), b.arrivals.len(), "{codec}");
    for (x, y) in a.arrivals.iter().zip(&b.arrivals) {
        assert_eq!(x.id, y.id, "{codec}");
        assert_eq!(x.t_s.to_bits(), y.t_s.to_bits(), "{codec} arrival {}", x.id);
        assert_eq!(x.deadline_s.to_bits(), y.deadline_s.to_bits(), "{codec} arrival {}", x.id);
        let (ex, ey) = (x.link.spectral_efficiency, y.link.spectral_efficiency);
        assert_eq!(ex.to_bits(), ey.to_bits(), "{codec} arrival {}", x.id);
    }
}

struct CodecRow {
    name: &'static str,
    bytes: usize,
    encode_s: f64,
    decode_s: f64,
}

fn main() {
    let mut cfg = ExperimentConfig::paper();
    cfg.arrival.rate_hz = 50.0;
    let horizon_s: f64 = std::env::var("BENCH_HORIZON_S")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2_000.0);
    let mut arrival = cfg.arrival;
    arrival.horizon_s = horizon_s;
    let trace = ArrivalTrace::generate(&cfg.scenario, &arrival, cfg.seed);
    assert!(trace.len() > 50_000, "trace too small to bench: {} requests", trace.len());

    // ---- round-trips + measurements ----
    let mut rows: Vec<CodecRow> = Vec::new();
    {
        let t0 = Instant::now();
        let bytes = columnar::encode(&trace);
        let encode_s = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let back = columnar::decode(&bytes).expect("columnar decode");
        let decode_s = t0.elapsed().as_secs_f64();
        assert_traces_bitwise(&trace, &back, "columnar");
        // Chunked framing reaches the same bytes-per-request envelope.
        let chunked = columnar::encode_chunked(&trace, 1024);
        assert_traces_bitwise(&trace, &columnar::decode(&chunked).expect("chunked"), "chunked");
        rows.push(CodecRow { name: "columnar", bytes: bytes.len(), encode_s, decode_s });
    }
    {
        let t0 = Instant::now();
        let text = trace.to_csv();
        let encode_s = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let back = ArrivalTrace::from_csv(&text).expect("csv decode");
        let decode_s = t0.elapsed().as_secs_f64();
        assert_traces_bitwise(&trace, &back, "csv");
        rows.push(CodecRow { name: "csv", bytes: text.len(), encode_s, decode_s });
    }
    {
        let t0 = Instant::now();
        let text = to_json(&trace);
        let encode_s = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let back = from_json(&text);
        let decode_s = t0.elapsed().as_secs_f64();
        assert_traces_bitwise(&trace, &back, "json");
        rows.push(CodecRow { name: "json", bytes: text.len(), encode_s, decode_s });
    }
    let by = |name: &str| rows.iter().find(|r| r.name == name).unwrap();
    assert!(
        by("columnar").bytes < by("csv").bytes && by("columnar").bytes < by("json").bytes,
        "columnar must be the smallest codec: {} vs csv {} / json {}",
        by("columnar").bytes,
        by("csv").bytes,
        by("json").bytes
    );

    // ---- fold into BENCH_pr8.json (after obs_overhead wrote it) ----
    let n = trace.len() as f64;
    let mut section = std::collections::BTreeMap::new();
    section.insert("requests".to_string(), Json::Num(n));
    for r in &rows {
        let mut codec = std::collections::BTreeMap::new();
        codec.insert("bytes".to_string(), Json::Num(r.bytes as f64));
        codec.insert("bytes_per_request".to_string(), Json::Num(r.bytes as f64 / n));
        codec.insert("encode_s".to_string(), Json::Num(r.encode_s));
        codec.insert("decode_s".to_string(), Json::Num(r.decode_s));
        codec.insert("encode_mreq_per_s".to_string(), Json::Num(n / r.encode_s / 1e6));
        codec.insert("decode_mreq_per_s".to_string(), Json::Num(n / r.decode_s / 1e6));
        section.insert(r.name.to_string(), Json::Obj(codec));
    }
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_pr8.json");
    let mut root = match std::fs::read_to_string(&path) {
        Ok(text) => json::parse(&text)
            .unwrap_or_else(|e| panic!("existing {} does not parse: {e}", path.display())),
        Err(_) => {
            let mut fresh = std::collections::BTreeMap::new();
            fresh.insert("pr".to_string(), Json::Num(8.0));
            Json::Obj(fresh)
        }
    };
    match &mut root {
        Json::Obj(map) => {
            map.insert("serialization".to_string(), Json::Obj(section));
        }
        other => panic!("BENCH_pr8.json root is not an object: {other:?}"),
    }
    let rendered = root.render();
    json::parse(&rendered).unwrap_or_else(|e| panic!("merged BENCH_pr8.json does not parse: {e}"));
    let mut out = rendered;
    out.push('\n');
    std::fs::write(&path, &out).unwrap_or_else(|e| panic!("writing {}: {e}", path.display()));
    println!(
        "\nfig_serialization OK ({} requests; columnar {} B, csv {} B, json {} B; \
         all codecs bit-identical; merged into {})",
        trace.len(),
        by("columnar").bytes,
        by("csv").bytes,
        by("json").bytes,
        path.display()
    );
}
