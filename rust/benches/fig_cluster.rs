//! Cluster routing λ-sweep — round-robin vs join-shortest-queue vs
//! quality-aware dispatch over a heterogeneous 4-server fleet.
//! (`harness = false`: criterion is not in the offline vendored set.)
//!
//! Acceptance properties asserted here (ISSUE 2):
//!  * the sweep covers ≥ 10⁴ simulated requests;
//!  * the whole run is deterministic — same seed, bit-identical rows;
//!  * every (λ, router) cell conserves requests;
//!  * under heavy load the load-aware policies (jsq, quality-aware)
//!    beat blind round-robin on fleet mean FID.

use aigc_edge::bench;
use aigc_edge::config::ExperimentConfig;
use aigc_edge::routing::RouterKind;

fn main() {
    let mut cfg = ExperimentConfig::paper();
    cfg.cluster.servers = 4;
    cfg.cluster.speed_min = 0.5;
    cfg.cluster.speed_max = 2.0;
    let lambdas = [0.5, 1.0, 2.0, 4.0, 8.0, 12.0, 16.0, 20.0];
    let horizon_s = std::env::var("BENCH_HORIZON_S")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200.0);

    let rows = bench::fig_cluster(&cfg, &lambdas, horizon_s);
    // Each λ reuses one trace across the router columns; count unique
    // arrivals once per λ.
    let total: usize = rows
        .iter()
        .filter(|r| r.router == RouterKind::RoundRobin)
        .map(|r| r.requests)
        .sum();
    assert!(
        total >= 10_000,
        "cluster λ-sweep must cover >= 10^4 simulated requests, got {total}"
    );

    // Deterministic replay: identical seed -> bit-identical rows.
    let replay = bench::fig_cluster(&cfg, &lambdas, horizon_s);
    assert_eq!(rows, replay, "cluster simulation is not deterministic");

    for r in &rows {
        assert!(r.served <= r.requests);
        assert!(r.outage_rate >= 0.0 && r.outage_rate <= 1.0);
        assert!(r.max_share > 0.0 && r.max_share <= 1.0);
    }

    // Load-aware routing must beat blind round-robin at the heaviest λ
    // on this heterogeneous fleet (the 0.5× server drowns under an
    // equal share).
    let heaviest = lambdas[lambdas.len() - 1];
    let fid = |kind: RouterKind| {
        rows.iter()
            .find(|r| r.lambda_hz == heaviest && r.router == kind)
            .map(|r| r.mean_quality)
            .unwrap()
    };
    let rr = fid(RouterKind::RoundRobin);
    let jsq = fid(RouterKind::JoinShortestQueue);
    let qa = fid(RouterKind::QualityAware);
    // Small relative slack: at total saturation quality compresses
    // across policies; the strict dominance claim is pinned by
    // tests/cluster_dominance.rs under a controlled load.
    assert!(
        jsq <= rr * 1.02 && qa <= rr * 1.02,
        "load-aware routing must not lose to round-robin at λ={heaviest}: \
         rr {rr:.2}, jsq {jsq:.2}, quality-aware {qa:.2}"
    );

    println!("\nfig_cluster OK ({total} simulated requests per router column)");
}
