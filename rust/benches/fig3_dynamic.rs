//! Fig. 3 — dynamic Poisson arrivals: sweep λ against delivered
//! quality, outage rate and tail latency through the event-driven
//! multi-epoch simulator. (`harness = false`: criterion is not in the
//! offline vendored set.)
//!
//! Acceptance properties asserted here:
//!  * the sweep covers ≥ 10⁴ simulated requests;
//!  * the whole run is deterministic — same seed, bit-identical rows;
//!  * load tells: mean FID and outage rate degrade from the lightest to
//!    the heaviest λ.

use aigc_edge::bench;
use aigc_edge::config::ExperimentConfig;

fn main() {
    let cfg = ExperimentConfig::paper();
    let lambdas = [0.5, 1.0, 2.0, 4.0, 8.0, 12.0, 16.0, 20.0];
    let horizon_s = std::env::var("BENCH_HORIZON_S")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200.0);

    let rows = bench::fig3_dynamic(&cfg, &lambdas, horizon_s);
    let total: usize = rows.iter().map(|r| r.requests).sum();
    assert!(
        total >= 10_000,
        "λ-sweep must cover >= 10^4 simulated requests, got {total}"
    );

    // Deterministic replay: identical seed -> bit-identical rows.
    let replay = bench::fig3_dynamic(&cfg, &lambdas, horizon_s);
    assert_eq!(rows, replay, "dynamic simulation is not deterministic");

    // Shape: overload costs quality and deadline hits.
    let first = rows.first().unwrap();
    let last = rows.last().unwrap();
    assert!(
        last.mean_quality > first.mean_quality,
        "mean FID must degrade with load: λ={} -> {:.2}, λ={} -> {:.2}",
        first.lambda_hz,
        first.mean_quality,
        last.lambda_hz,
        last.mean_quality
    );
    assert!(
        last.outage_rate >= first.outage_rate,
        "outage rate must not improve with load"
    );
    // Every request is accounted for in every row.
    for r in &rows {
        assert!(r.served <= r.requests);
        assert!(r.outage_rate >= 0.0 && r.outage_rate <= 1.0);
    }
    println!("\nfig3_dynamic OK ({total} simulated requests)");
}
