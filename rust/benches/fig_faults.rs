//! Fault sweep — failure rate × migration policy through the
//! shared-clock event engine on a heterogeneous 4-server fleet.
//! (`harness = false`: criterion is not in the offline vendored set.)
//!
//! Acceptance properties asserted here (ISSUE 3):
//!  * the sweep covers ≥ 10⁴ simulated requests;
//!  * the whole run is deterministic — same seed, bit-identical rows;
//!  * with an empty fault script and no migration, the event engine
//!    reproduces `simulate_cluster` fleet stats bit-for-bit;
//!  * on a heterogeneous fleet with mid-trace failures,
//!    requeue-on-death strictly beats no-migration on drop count and
//!    on the deadline-censored post-failure p99 tail at fixed λ.

use aigc_edge::bandwidth::EqualAllocator;
use aigc_edge::bench;
use aigc_edge::config::ExperimentConfig;
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::faults::{DownInterval, FaultScript, MigrationPolicyKind};
use aigc_edge::quality::PowerLawQuality;
use aigc_edge::scheduler::Stacking;
use aigc_edge::sim::{
    simulate_cluster, simulate_event_cluster, ClusterConfig, EventClusterConfig,
};
use aigc_edge::trace::ArrivalTrace;

fn main() {
    let mut cfg = ExperimentConfig::paper();
    cfg.cluster.servers = 4;
    cfg.cluster.speed_min = 0.5;
    cfg.cluster.speed_max = 2.0;
    cfg.arrival.rate_hz = 8.0;
    let horizon_s: f64 = std::env::var("BENCH_HORIZON_S")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400.0);

    // ---- failure-rate × migration-policy sweep ----
    let fault_rates = [0.0, 0.5, 1.0, 2.0];
    let rows = bench::fig_faults(&cfg, &fault_rates, horizon_s);
    // Each rate draws its own trace, reused across the policy columns;
    // count unique arrivals once per rate.
    let total: usize = rows
        .iter()
        .filter(|r| r.policy == MigrationPolicyKind::None)
        .map(|r| r.requests)
        .sum();
    assert!(total >= 10_000, "fault sweep must cover >= 10^4 simulated requests, got {total}");

    // Deterministic replay: identical seed -> bit-identical rows.
    let replay = bench::fig_faults(&cfg, &fault_rates, horizon_s);
    assert_eq!(rows, replay, "fault-aware simulation is not deterministic");

    for r in &rows {
        assert_eq!(r.served + r.dropped, r.requests);
        assert!(r.lost_to_failure <= r.dropped);
        if r.fault_rate_per_min == 0.0 {
            assert_eq!(r.failures, 0);
            assert_eq!(r.lost_to_failure, 0);
            // steal-when-idle reacts to idleness, not faults, so it
            // may legitimately migrate on a fault-free fleet
            if r.policy != MigrationPolicyKind::StealWhenIdle {
                assert_eq!(r.migrated, 0);
            }
        }
    }

    // ---- zero-fault bit-identity against the sequential cluster ----
    let scheduler = Stacking::default();
    let allocator = EqualAllocator;
    let delay = BatchDelayModel::new(cfg.delay.a, cfg.delay.b);
    let quality = PowerLawQuality::paper();
    let mut arrival = cfg.arrival;
    arrival.horizon_s = 60.0;
    let short = ArrivalTrace::generate(&cfg.scenario, &arrival, cfg.seed);
    let cluster_cfg = ClusterConfig::from_settings(&cfg.cluster, &cfg.dynamic);
    let seq = simulate_cluster(&short, &scheduler, &allocator, &delay, &quality, &cluster_cfg);
    let ev = simulate_event_cluster(
        &short,
        &scheduler,
        &allocator,
        &delay,
        &quality,
        &EventClusterConfig::fault_free(&cluster_cfg),
    );
    assert_eq!(ev.assignment, seq.assignment, "zero-fault dispatch must match route_trace");
    let (a, b) = (ev.fleet_stats(), seq.fleet_stats());
    assert_eq!(a.count, b.count);
    assert_eq!(a.served, b.served);
    assert_eq!(a.mean_quality.to_bits(), b.mean_quality.to_bits());
    assert_eq!(a.outage_rate.to_bits(), b.outage_rate.to_bits());
    assert_eq!(a.p99_e2e_s.to_bits(), b.p99_e2e_s.to_bits());
    assert_eq!(ev.horizon_s.to_bits(), seq.horizon_s.to_bits());

    // ---- controlled mid-trace failures: requeue vs none showdown ----
    // The fastest server (largest JSQ share) dies for good at H/3 and
    // the second-fastest drops out for a window: without migration
    // their queued work is lost; with requeue-on-death it re-enters
    // the router with its residual deadline budget.
    let mut showdown_arrival = cfg.arrival;
    showdown_arrival.rate_hz = 6.0;
    showdown_arrival.horizon_s = horizon_s;
    let trace = ArrivalTrace::generate(&cfg.scenario, &showdown_arrival, cfg.seed);
    let script = FaultScript::scheduled(vec![
        DownInterval::new(3, horizon_s / 3.0, horizon_s + 60.0).unwrap(),
        DownInterval::new(2, horizon_s / 2.0, horizon_s / 2.0 + 40.0).unwrap(),
    ])
    .unwrap();
    let showdown_speeds = aigc_edge::sim::server_speeds(4, 0.5, 2.0);
    let run = |migration: MigrationPolicyKind| {
        let event_cfg = EventClusterConfig {
            speeds: &showdown_speeds,
            router: cfg.cluster.router,
            dynamic: (&cfg.dynamic).into(),
            faults: &script,
            resume_transfer_s: cfg.migration.transfer_s,
            migration,
        };
        simulate_event_cluster(&trace, &scheduler, &allocator, &delay, &quality, &event_cfg)
    };
    let none = run(MigrationPolicyKind::None);
    let requeue = run(MigrationPolicyKind::RequeueOnDeath);
    assert!(none.lost_to_failure() > 0, "the scheduled failures must strand queued work");
    assert!(requeue.migrated() > 0, "requeue must hand stranded work to the survivors");
    assert!(
        requeue.dropped() < none.dropped(),
        "requeue-on-death must strictly beat no-migration on drops: {} vs {}",
        requeue.dropped(),
        none.dropped()
    );
    let window_s = cfg.dynamic.window_s;
    let (rs_none, rs_requeue) = (none.recovery_stats(window_s), requeue.recovery_stats(window_s));
    assert!(
        rs_requeue.post_failure_p99_s < rs_none.post_failure_p99_s,
        "requeue must strictly beat no-migration on the censored post-failure p99: {} vs {}",
        rs_requeue.post_failure_p99_s,
        rs_none.post_failure_p99_s
    );

    println!(
        "\nfig_faults OK ({total} simulated requests; showdown drops {} -> {}, post-failure p99 {:.2}s -> {:.2}s)",
        none.dropped(),
        requeue.dropped(),
        rs_none.post_failure_p99_s,
        rs_requeue.post_failure_p99_s
    );
}
