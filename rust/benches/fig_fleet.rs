//! Fleet-scale dispatch gates behind `BENCH_pr10.json`.
//! (`harness = false`: criterion is not in the offline vendored set.)
//!
//! Acceptance properties asserted here (ISSUE 10):
//!  * indexed routing is decision-identical to the O(N) reference scan
//!    for every RouterKind in the sweep, including `route_resume`
//!    probes with fresh, partial and saturating step credits;
//!  * per-arrival routing cost — the index's deterministic op counters,
//!    not wall clock — grows sub-linearly in fleet size across
//!    N ∈ {4, 64, 512, 4096};
//!  * the whole sweep replays bit-identically;
//!  * at engine level, `simulate_event_cluster` (indexed) and
//!    `simulate_event_cluster_scan` produce bitwise-identical runs on
//!    a faulted, cache-enabled, checkpoint-migrating cluster — the
//!    reroute/steal/resume dispatch sites included.

use std::path::Path;

use aigc_edge::bandwidth::EqualAllocator;
use aigc_edge::bench;
use aigc_edge::cache::CacheSettings;
use aigc_edge::config::{ArrivalProcessKind, ArrivalSettings, ExperimentConfig};
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::faults::{FaultScript, MigrationPolicyKind};
use aigc_edge::quality::PowerLawQuality;
use aigc_edge::routing::RouterKind;
use aigc_edge::scheduler::Stacking;
use aigc_edge::sim::{
    server_speeds, simulate_event_cluster, simulate_event_cluster_scan, EventClusterConfig,
    EventReport,
};
use aigc_edge::trace::ArrivalTrace;

fn assert_bitwise(a: &EventReport, b: &EventReport, tag: &str) {
    assert_eq!(a.assignment, b.assignment, "{tag}: assignment diverged");
    assert_eq!(a.horizon_s.to_bits(), b.horizon_s.to_bits(), "{tag}: horizon diverged");
    assert_eq!(a.migrations.len(), b.migrations.len(), "{tag}: migrations diverged");
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.disposition, y.disposition, "{tag}: request {}", x.id);
        assert_eq!(x.steps, y.steps, "{tag}: request {}", x.id);
        assert_eq!(x.quality.to_bits(), y.quality.to_bits(), "{tag}: request {}", x.id);
        assert_eq!(x.resolved_s.to_bits(), y.resolved_s.to_bits(), "{tag}: request {}", x.id);
    }
}

fn main() {
    let max_requests: usize = std::env::var("BENCH_FLEET_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2000);
    let sizes = [4usize, 64, 512, 4096];
    let kinds = [RouterKind::JoinShortestQueue, RouterKind::QualityAware, RouterKind::CacheAware];

    // ---- the sweep: decision identity + deterministic op counts ----
    let rows = bench::fig_fleet(&sizes, &kinds, max_requests, 10);
    assert_eq!(rows.len(), sizes.len() * kinds.len());
    let by = |n: usize, router: RouterKind| {
        rows.iter()
            .find(|r| r.n == n && r.router == router)
            .unwrap_or_else(|| panic!("missing cell ({n}, {})", router.name()))
    };
    for r in &rows {
        assert!(
            r.identical,
            "indexed routing diverged from the scan: {} at N={}",
            r.router.name(),
            r.n
        );
        assert!(
            r.resume_identical,
            "indexed route_resume diverged from the scan: {} at N={}",
            r.router.name(),
            r.n
        );
        assert_eq!(r.arrivals, max_requests, "trace did not fill the request cap");
    }

    // ---- sub-linear per-arrival cost in N ----
    // The fleet grows 1024x from N=4 to N=4096; a linear scan grows its
    // per-arrival cost by the same factor. The index must hold the
    // growth to ~log-like territory — two orders of magnitude below
    // linear — and stay under an absolute per-arrival ceiling.
    for router in kinds {
        let small = by(4, router).ops_per_arrival;
        let large = by(4096, router).ops_per_arrival;
        assert!(
            large <= small * 64.0,
            "{}: per-arrival ops grew {:.1}x from N=4 ({small:.2}) to N=4096 ({large:.2}) — not \
             sub-linear (linear would be 1024x)",
            router.name(),
            large / small
        );
        assert!(
            large <= 128.0,
            "{}: {large:.2} ops per arrival at N=4096 exceeds the absolute ceiling",
            router.name()
        );
    }

    // ---- bitwise replay ----
    let replay = bench::fig_fleet(&sizes, &kinds, max_requests, 10);
    for (a, b) in rows.iter().zip(&replay) {
        assert_eq!(a.key(), b.key(), "fleet sweep is not deterministic");
    }

    // ---- engine-level bitwise identity under faults ----
    // Checkpoint migration on a faulted, cache-enabled cluster drives
    // every dispatch site: arrivals, death reroutes, checkpoint
    // resumes, recovery re-dispatches.
    let cfg = ExperimentConfig::paper();
    let arrival = ArrivalSettings {
        process: ArrivalProcessKind::Poisson,
        rate_hz: 8.0,
        burst_rate_hz: 8.0,
        period_s: 60.0,
        duty: 0.5,
        horizon_s: 90.0,
        max_requests: 0,
        prompt_universe: 32,
        zipf_s: 1.4,
        models: 3,
    };
    let marked = ArrivalTrace::generate(&cfg.scenario, &arrival, 17);
    let scheduler = Stacking::default();
    let allocator = EqualAllocator;
    let delay = BatchDelayModel::paper();
    let quality = PowerLawQuality::paper();
    let speeds = server_speeds(8, 0.5, 2.0);
    let mut engine_cells = 0usize;
    let engine_kinds = [
        RouterKind::JoinShortestQueue,
        RouterKind::QualityAware,
        RouterKind::LiveState,
        RouterKind::CacheAware,
    ];
    for router in engine_kinds {
        let script = FaultScript::random(8, 90.0, 30.0, 10.0, 23);
        let mut dynamic: aigc_edge::sim::DynamicConfig = (&cfg.dynamic).into();
        if router == RouterKind::CacheAware {
            dynamic.cache =
                CacheSettings { enabled: true, capacity: 16, ..CacheSettings::default() };
        }
        let event_cfg = EventClusterConfig {
            speeds: &speeds,
            router,
            dynamic,
            faults: &script,
            migration: MigrationPolicyKind::Checkpoint,
            resume_transfer_s: 0.5,
        };
        let indexed =
            simulate_event_cluster(&marked, &scheduler, &allocator, &delay, &quality, &event_cfg);
        let scan = simulate_event_cluster_scan(
            &marked,
            &scheduler,
            &allocator,
            &delay,
            &quality,
            &event_cfg,
        );
        assert_bitwise(&indexed, &scan, router.name());
        engine_cells += 1;
    }

    // ---- tracked trajectory: BENCH_pr10.json at the repository root ----
    let mut cells = String::new();
    for (i, r) in rows.iter().enumerate() {
        if i > 0 {
            cells.push_str(",\n");
        }
        cells.push_str(&format!(
            "    \"n{}_{}\": {{\n      \"identical\": {},\n      \"resume_identical\": {},\n      \
             \"queries\": {},\n      \"examined\": {},\n      \"settles\": {},\n      \
             \"ops_per_arrival\": {:?},\n      \"assignment_fnv\": {},\n      \
             \"indexed_ms\": {:?},\n      \"scan_ms\": {:?}\n    }}",
            r.n,
            r.router.name(),
            r.identical,
            r.resume_identical,
            r.queries,
            r.examined,
            r.settles,
            r.ops_per_arrival,
            r.assignment_fnv,
            r.indexed_ms,
            r.scan_ms,
        ));
    }
    let json = format!(
        "{{\n  \"pr\": 10,\n  \"arrivals\": {max_requests},\n  \"engine_cells\": {engine_cells},\n  \
         \"cells\": {{\n{cells}\n  }}\n}}\n"
    );
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_pr10.json");
    std::fs::write(&path, &json).unwrap_or_else(|e| panic!("writing {}: {e}", path.display()));
    aigc_edge::util::json::parse(&json)
        .unwrap_or_else(|e| panic!("BENCH_pr10.json does not parse: {e}"));

    let jsq = by(4096, RouterKind::JoinShortestQueue);
    let qa = by(4096, RouterKind::QualityAware);
    println!(
        "\nfig_fleet OK ({} cells identical incl. resumes; N=4096 ops/arrival: jsq {:.2}, \
         quality {:.2}; {} engine cells bitwise; wrote {})",
        rows.len(),
        jsq.ops_per_arrival,
        qa.ops_per_arrival,
        engine_cells,
        path.display()
    );
}
