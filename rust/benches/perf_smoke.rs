//! CI perf smoke for the parallel solve fabric (`util::exec`): runs
//! the three tracked hot loops (per-epoch PSO solve, per-server
//! cluster epochs, sweep cells) at threads = 1 vs auto.
//!
//! The **bit-identity assert is blocking** — a parallel output that
//! diverges from serial is a determinism bug, never noise. The
//! wall-clock numbers are emitted to `BENCH_pr5.json` (uploaded as a
//! CI artifact) with a **soft** speedup threshold: shared CI runners
//! can be throttled to one effective core, so a hard gate would flake.
//! On a quiet ≥4-core machine (`aigc-edge perf`, full sizes) the PSO
//! solve and the sweep each clear 2×.

use aigc_edge::bench::perf::{bench_json, default_bench_path, run_perf, PerfOptions};
use aigc_edge::config::ExperimentConfig;
use aigc_edge::util::resolve_threads;

fn main() {
    let cfg = ExperimentConfig::paper();
    let opts = PerfOptions { threads: 0, quick: true };
    let auto = resolve_threads(opts.threads);
    println!("perf_smoke: serial (1 thread) vs parallel ({auto} threads), quick sizes");
    let rows = run_perf(&cfg, &opts);
    for r in &rows {
        println!(
            "  {:<14} serial {:.4}s  parallel {:.4}s  speedup {:.2}x  bit-identical {}",
            r.loop_name,
            r.serial_s,
            r.parallel_s,
            r.speedup(),
            r.bit_identical
        );
        // BLOCKING: the fabric's whole contract is bitwise replay.
        assert!(r.bit_identical, "{}: parallel output diverged from serial", r.loop_name);
        // SOFT: report, don't gate — runner capacity varies.
        if auto >= 4 && r.speedup() < 1.2 {
            println!(
                "  warning: {} speedup {:.2}x < 1.2x at {auto} threads (shared runner?)",
                r.loop_name,
                r.speedup()
            );
        }
    }
    let path = default_bench_path();
    std::fs::write(&path, bench_json(&rows, &opts))
        .unwrap_or_else(|e| panic!("writing {}: {e}", path.display()));
    println!("perf_smoke: wrote {}", path.display());
    println!("perf_smoke OK — parallel ≡ serial bitwise on all {} tracked loops", rows.len());
}
