//! Fig. 1a — denoising delay vs batch size, measured on the real PJRT
//! runtime, with the aX+b fit printed against the paper's constants.
//! (`harness = false`: criterion is not in the offline vendored set.)

use aigc_edge::bench;
use aigc_edge::config::default_artifacts_dir;
use aigc_edge::runtime::ArtifactStore;

fn main() {
    // single-threaded XLA: on a many-core CPU the tiny model's per-task
    // compute is otherwise parallelized away and the slope `a` vanishes
    aigc_edge::coordinator::pin_xla_single_threaded();
    let reps = std::env::var("BENCH_REPS").ok().and_then(|s| s.parse().ok()).unwrap_or(20);
    let store = ArtifactStore::load(&default_artifacts_dir())
        .expect("artifacts missing — run `make artifacts`");
    let rows = bench::fig1a(&store, reps);
    // Shape assertions (the figure's claims):
    // delay grows with batch size, but per-task delay falls.
    let first = rows.first().unwrap();
    let last = rows.last().unwrap();
    assert!(last.1 > first.1, "total delay must grow with batch size");
    assert!(
        (last.1 / last.0 as f64) < (first.1 / first.0 as f64),
        "per-task delay must fall with batch size (amortization)"
    );
    println!("\nfig1a OK");
}
