//! The 10⁷-request scale sweep behind `BENCH_pr6.json`.
//!
//! Defaults to CI size (10⁵ requests per λ cell); set
//! `FIG_SCALE_FULL=1` for the full 10⁷-per-cell run (minutes of wall
//! clock, still flat memory). Three blocking asserts:
//!
//! * streaming percentiles within `⌈eps·n⌉ + 1` ranks of exact on a
//!   materialized 10⁵ stream (agreement);
//! * sketch support under the O((1/eps)·log(eps·n)) bound in every
//!   cell (memory flatness — per-request state leaking into the
//!   streaming path trips this no matter the sweep size);
//! * bitwise replay of a cell (determinism — the sketch has no
//!   randomness and no clocks).

use aigc_edge::bench::scale::{
    default_scale_path, run_scale, scale_json, verify_agreement, ScaleOptions,
};
use aigc_edge::config::ExperimentConfig;

fn main() {
    let full = std::env::var("FIG_SCALE_FULL").map(|v| v == "1").unwrap_or(false);
    let mut opts = ScaleOptions::default();
    if full {
        opts.requests_per_cell = 10_000_000;
    }
    let cfg = ExperimentConfig::paper();
    println!(
        "fig_scale: {} requests per λ cell over λ = {:?}, sketch eps {}",
        opts.requests_per_cell,
        opts.lambdas,
        opts.sketch_eps
    );

    // BLOCKING: streaming percentiles must track exact within the
    // documented rank budget — checked on a materialized stream that
    // fits in memory (10⁵), independently of the sweep size.
    let verify_opts = ScaleOptions { requests_per_cell: 100_000, ..opts.clone() };
    let worst = verify_agreement(&cfg, &verify_opts, verify_opts.lambdas[0])
        .unwrap_or_else(|e| panic!("sketch-vs-exact agreement failed: {e}"));
    println!("agreement at 1e5: worst percentile sits {worst} ranks from its exact target");

    let rows = run_scale(&cfg, &opts);
    for r in &rows {
        // BLOCKING: flat memory — `support` is the entire per-request
        // state retained and must obey the logarithmic bound.
        assert!(
            r.support <= r.support_bound,
            "λ={}: sketch support {} exceeds flatness bound {}",
            r.rate_hz,
            r.support,
            r.support_bound
        );
        println!(
            "  λ={:<5} {:>9} req  served {:>9}  outage {:.3}  p50 {:.2}s p95 {:.2}s p99 {:.2}s  support {:>4}/{:<4}  {:>8.2}s wall",
            r.rate_hz,
            r.requests,
            r.served,
            r.outage_rate,
            r.p50_e2e_s,
            r.p95_e2e_s,
            r.p99_e2e_s,
            r.support,
            r.support_bound,
            r.wall_s
        );
    }

    // BLOCKING: replaying a cell must reproduce every output float
    // bit-for-bit.
    let small = ScaleOptions {
        lambdas: vec![opts.lambdas[0]],
        requests_per_cell: 20_000,
        ..opts.clone()
    };
    let a = &run_scale(&cfg, &small)[0];
    let b = &run_scale(&cfg, &small)[0];
    assert_eq!(a.requests, b.requests, "replay diverged on request count");
    assert!(
        a.p50_e2e_s.to_bits() == b.p50_e2e_s.to_bits()
            && a.p95_e2e_s.to_bits() == b.p95_e2e_s.to_bits()
            && a.p99_e2e_s.to_bits() == b.p99_e2e_s.to_bits(),
        "replay diverged bitwise"
    );

    let path = default_scale_path();
    std::fs::write(&path, scale_json(&rows, &opts))
        .unwrap_or_else(|e| panic!("writing {}: {e}", path.display()));
    println!("fig_scale: wrote {}", path.display());
    println!("fig_scale OK — flat memory, sketch ≡ exact within budget, bitwise replay");
}
