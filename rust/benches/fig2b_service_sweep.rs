//! Fig. 2b — mean FID vs number of services, five schemes.
//! BENCH_REPS controls seeds per point (default 3).

use aigc_edge::bench;
use aigc_edge::config::ExperimentConfig;

fn main() {
    let reps = std::env::var("BENCH_REPS").ok().and_then(|s| s.parse().ok()).unwrap_or(3);
    let mut cfg = ExperimentConfig::paper();
    // moderate PSO budget: the sweep runs 8 K-values x 5 schemes x reps
    cfg.pso.particles = 12;
    cfg.pso.iterations = 16;
    cfg.pso.patience = 8;
    let ks = [5, 10, 15, 20, 25, 30, 35, 40];
    let rows = bench::fig2b(&cfg, &ks, reps);

    // The figure's claims:
    for (k, vals) in &rows {
        // proposed (index 0) is the best scheme everywhere
        for (i, v) in vals.iter().enumerate() {
            assert!(vals[0] <= v * 1.02 + 1e-9, "K={k}: scheme {i} beats proposed");
        }
    }
    // mean FID grows with K for every scheme (quality degrades with load)
    let first = &rows[0].1;
    let last = &rows[rows.len() - 1].1;
    assert!(last[0] > first[0], "proposed should degrade with K");
    // single-instance (index 1) collapses much faster than proposed
    assert!(
        (last[1] - first[1]) > 2.0 * (last[0] - first[0]),
        "single-instance must collapse fastest"
    );
    println!("\nfig2b OK");
}
