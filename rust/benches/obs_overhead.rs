//! Flight-recorder gate behind `BENCH_pr8.json`.
//! (`harness = false`: criterion is not in the offline vendored set.)
//!
//! Acceptance properties asserted here (ISSUE 8):
//!  * the NullSink default is *bitwise* free: every engine output is
//!    identical with tracing off and with a full Recorder capture —
//!    on the sequential cluster engine and on the event engine under
//!    the seed-7 random fault script with checkpointed migration;
//!  * every capture passes the lifecycle audit with zero violations
//!    and conserves the request count;
//!  * the columnar span file round-trips bit-for-bit;
//!  * the seed-7 faulted capture replays bit-identically, so its
//!    perfetto export is byte-identical across runs;
//!  * full-capture overhead is *measured* and reported (not gated —
//!    wall-clock on shared CI is noise, bit-identity is the contract).

use std::path::Path;
use std::time::Instant;

use aigc_edge::bandwidth::EqualAllocator;
use aigc_edge::config::ExperimentConfig;
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::faults::{FaultScript, MigrationPolicyKind};
use aigc_edge::obs::{audit, perfetto, span, Recorder, TraceEvent};
use aigc_edge::quality::PowerLawQuality;
use aigc_edge::scheduler::Stacking;
use aigc_edge::sim::{
    server_speeds, simulate_cluster, simulate_cluster_traced, simulate_event_cluster,
    simulate_event_cluster_traced, ClusterConfig, ClusterReport, EventClusterConfig, EventReport,
    RequestOutcome,
};
use aigc_edge::trace::ArrivalTrace;

fn assert_outcomes_bitwise(plain: &[RequestOutcome], traced: &[RequestOutcome]) {
    assert_eq!(plain.len(), traced.len());
    for (a, b) in plain.iter().zip(traced) {
        assert_eq!(a.disposition, b.disposition, "request {}", a.id);
        assert_eq!(a.steps, b.steps, "request {}", a.id);
        assert_eq!(a.quality.to_bits(), b.quality.to_bits(), "request {}", a.id);
        assert_eq!(a.e2e_s.to_bits(), b.e2e_s.to_bits(), "request {}", a.id);
        assert_eq!(a.resolved_s.to_bits(), b.resolved_s.to_bits(), "request {}", a.id);
    }
}

fn assert_events_bitwise(x: &[TraceEvent], y: &[TraceEvent]) {
    assert_eq!(x.len(), y.len(), "event counts diverged");
    for (a, b) in x.iter().zip(y) {
        assert_eq!(a.t_s.to_bits(), b.t_s.to_bits());
        assert_eq!((a.server, a.request, a.kind), (b.server, b.request, b.kind));
    }
}

fn main() {
    let mut cfg = ExperimentConfig::paper();
    cfg.cluster.servers = 4;
    cfg.cluster.speed_min = 0.5;
    cfg.cluster.speed_max = 2.0;
    cfg.arrival.rate_hz = 6.0;
    let horizon_s: f64 = std::env::var("BENCH_HORIZON_S")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400.0);
    let reps: usize = std::env::var("BENCH_REPS").ok().and_then(|s| s.parse().ok()).unwrap_or(3);

    let scheduler = Stacking::default();
    let allocator = EqualAllocator;
    let delay = BatchDelayModel::new(cfg.delay.a, cfg.delay.b);
    let quality = PowerLawQuality::paper();
    let mut arrival = cfg.arrival;
    arrival.horizon_s = horizon_s;
    let trace = ArrivalTrace::generate(&cfg.scenario, &arrival, cfg.seed);
    assert!(trace.len() > 1_000, "workload too small: {} requests", trace.len());
    let cluster_cfg = ClusterConfig {
        speeds: server_speeds(4, 0.5, 2.0),
        router: cfg.cluster.router,
        dynamic: (&cfg.dynamic).into(),
    };

    // ---- sequential cluster: tracing off == full capture, bitwise ----
    let run_seq =
        || simulate_cluster(&trace, &scheduler, &allocator, &delay, &quality, &cluster_cfg);
    let run_seq_traced = |rec: &mut Recorder| -> ClusterReport {
        simulate_cluster_traced(&trace, &scheduler, &allocator, &delay, &quality, &cluster_cfg, rec)
    };
    let plain = run_seq();
    let mut rec = Recorder::new();
    let traced = run_seq_traced(&mut rec);
    assert_eq!(plain.assignment, traced.assignment, "capture changed routing");
    assert_eq!(plain.horizon_s.to_bits(), traced.horizon_s.to_bits());
    assert_outcomes_bitwise(&plain.outcomes, &traced.outcomes);
    assert!(rec.events.len() >= 3 * trace.len(), "capture too sparse: {}", rec.events.len());
    let seq_audit = audit::audit_expecting(&rec.events, trace.len());
    assert!(seq_audit.is_clean(), "{}", seq_audit.render());

    // ---- span file round-trip ----
    let bytes = span::encode(&rec.events);
    let decoded = span::decode(&bytes).expect("span decode");
    assert_events_bitwise(&rec.events, &decoded);

    // ---- event engine under the seed-7 fault script ----
    let faults = FaultScript::random(4, horizon_s, 90.0, 12.0, 7);
    assert!(!faults.downs().is_empty(), "seed-7 script injected no faults");
    let event_cfg = EventClusterConfig {
        speeds: &cluster_cfg.speeds,
        router: cfg.cluster.router,
        dynamic: (&cfg.dynamic).into(),
        faults: &faults,
        migration: MigrationPolicyKind::Checkpoint,
        resume_transfer_s: 0.05,
    };
    let run_ev =
        || simulate_event_cluster(&trace, &scheduler, &allocator, &delay, &quality, &event_cfg);
    let capture_ev = || -> (EventReport, Vec<TraceEvent>) {
        let mut r = Recorder::new();
        let rep = simulate_event_cluster_traced(
            &trace,
            &scheduler,
            &allocator,
            &delay,
            &quality,
            &event_cfg,
            &mut r,
        );
        (rep, r.events)
    };
    let ev_plain = run_ev();
    let (ev_traced, events) = capture_ev();
    assert_eq!(ev_plain.assignment, ev_traced.assignment, "capture changed routing under faults");
    assert_eq!(ev_plain.horizon_s.to_bits(), ev_traced.horizon_s.to_bits());
    assert_outcomes_bitwise(&ev_plain.outcomes, &ev_traced.outcomes);
    let ev_audit = audit::audit_expecting(&events, trace.len());
    assert!(ev_audit.is_clean(), "{}", ev_audit.render());

    // ---- deterministic replay: byte-identical perfetto timeline ----
    let (_, events2) = capture_ev();
    assert_events_bitwise(&events, &events2);
    let timeline = perfetto::export(&events);
    assert_eq!(timeline, perfetto::export(&events2), "perfetto export is not deterministic");

    // ---- overhead: NullSink path vs full Recorder capture ----
    let time = |f: &dyn Fn()| {
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let t0 = Instant::now();
            f();
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best
    };
    let seq_off_s = time(&|| drop(run_seq()));
    let seq_on_s = time(&|| {
        let mut r = Recorder::new();
        run_seq_traced(&mut r);
    });
    let ev_off_s = time(&|| drop(run_ev()));
    let ev_on_s = time(&|| drop(capture_ev()));
    let t0 = Instant::now();
    let span_bytes = span::encode(&events);
    let encode_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    span::decode(&span_bytes).expect("span decode");
    let decode_s = t0.elapsed().as_secs_f64();
    let pct = |off: f64, on: f64| if off > 0.0 { 100.0 * (on - off) / off } else { 0.0 };

    // ---- tracked trajectory: BENCH_pr8.json at the repository root ----
    let json = format!(
        "{{\n  \"pr\": 8,\n  \"horizon_s\": {horizon_s:?},\n  \"requests\": {},\n  \
         \"flight_recorder\": {{\n    \"cluster_events\": {},\n    \"event_engine_events\": {},\n    \
         \"span_bytes\": {},\n    \"bytes_per_event\": {:?},\n    \"perfetto_bytes\": {},\n    \
         \"cluster_off_s\": {:?},\n    \"cluster_capture_s\": {:?},\n    \
         \"cluster_overhead_pct\": {:?},\n    \"event_off_s\": {:?},\n    \
         \"event_capture_s\": {:?},\n    \"event_overhead_pct\": {:?},\n    \
         \"span_encode_s\": {:?},\n    \"span_decode_s\": {:?},\n    \
         \"audit_violations\": {}\n  }}\n}}\n",
        trace.len(),
        rec.events.len(),
        events.len(),
        span_bytes.len(),
        span_bytes.len() as f64 / events.len().max(1) as f64,
        timeline.len(),
        seq_off_s,
        seq_on_s,
        pct(seq_off_s, seq_on_s),
        ev_off_s,
        ev_on_s,
        pct(ev_off_s, ev_on_s),
        encode_s,
        decode_s,
        ev_audit.violations.len(),
    );
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_pr8.json");
    std::fs::write(&path, &json).unwrap_or_else(|e| panic!("writing {}: {e}", path.display()));
    aigc_edge::util::json::parse(&json)
        .unwrap_or_else(|e| panic!("BENCH_pr8.json does not parse: {e}"));
    println!(
        "\nobs_overhead OK ({} + {} events, {} span bytes; capture overhead {:.1}% cluster / \
         {:.1}% event engine; audits clean; wrote {})",
        rec.events.len(),
        events.len(),
        span_bytes.len(),
        pct(seq_off_s, seq_on_s),
        pct(ev_off_s, ev_on_s),
        path.display()
    );
}
