//! Fig. 2a — end-to-end delay illustration for K = 10 services under
//! the proposed algorithm (STACKING + PSO).

use aigc_edge::bench;
use aigc_edge::config::ExperimentConfig;

fn main() {
    let cfg = ExperimentConfig::paper();
    let rows = bench::fig2a(&cfg);
    // The figure's claims: every service meets its deadline, tighter
    // deadlines get (weakly) fewer steps, transmissions end near the
    // deadline so generation gets the slack.
    for &(id, deadline, _gen, _tx, e2e, steps) in &rows {
        assert!(steps > 0, "service {id} starved");
        assert!(e2e <= deadline + 1e-9, "service {id} misses deadline");
    }
    // rows are sorted by deadline: step counts must be weakly increasing
    // (services with similar deadlines get similar step counts)
    for w in rows.windows(2) {
        assert!(w[1].5 + 3 >= w[0].5, "step monotonicity violated: {:?}", rows);
    }
    println!("\nfig2a OK");
}
