//! Micro-benchmarks of the request-path hot spots (the §Perf numbers in
//! EXPERIMENTS.md): STACKING solve, PSO objective eval, PJRT execution
//! per bucket, artifact load. harness=false — plain Instant timing with
//! warmup and median-of-N.

use aigc_edge::bandwidth::EqualAllocator;
use aigc_edge::config::{default_artifacts_dir, ExperimentConfig};
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::quality::PowerLawQuality;
use aigc_edge::runtime::{ArtifactStore, BatchInput, DenoiseExecutor};
use aigc_edge::scheduler::{BatchScheduler, Stacking};
use aigc_edge::sim::{gen_budgets, solve_joint};
use aigc_edge::trace::generate;
use aigc_edge::util::Pcg64;

fn median_of<F: FnMut() -> ()>(n: usize, mut f: F) -> f64 {
    let mut times: Vec<f64> = (0..n)
        .map(|_| {
            let t0 = std::time::Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[n / 2]
}

fn main() {
    let cfg = ExperimentConfig::paper();
    let delay = BatchDelayModel::paper();
    let quality = PowerLawQuality::paper();
    let reps = std::env::var("BENCH_REPS").ok().and_then(|s| s.parse().ok()).unwrap_or(15);

    // ---- STACKING solve (the PSO inner objective) ----
    for k in [10usize, 20, 40] {
        let mut scenario = cfg.scenario.clone();
        scenario.num_services = k;
        let w = generate(&scenario, 1);
        let services = gen_budgets(&w, &vec![w.total_bandwidth_hz / k as f64; k]);
        let sched = Stacking::default();
        // warmup
        let _ = sched.schedule(&services, &delay, &quality);
        let t = median_of(reps, || {
            let _ = sched.schedule(&services, &delay, &quality);
        });
        println!("stacking_solve K={k:<3}           {:>10.3} ms", t * 1e3);
    }

    // ---- full joint solve (PSO outer) ----
    {
        let w = generate(&cfg.scenario, 1);
        let mut c = cfg.clone();
        c.pso.particles = 8;
        c.pso.iterations = 10;
        let alloc = aigc_edge::bandwidth::PsoAllocator::new(aigc_edge::bandwidth::PsoConfig {
            particles: c.pso.particles,
            iterations: c.pso.iterations,
            patience: 0,
            ..Default::default()
        });
        let t = median_of(5, || {
            let _ = solve_joint(&w, &Stacking::default(), &alloc, &delay, &quality);
        });
        println!("joint_solve K=20 (8x10 pso)     {:>10.3} ms", t * 1e3);
        let t_eq = median_of(reps, || {
            let _ = solve_joint(&w, &Stacking::default(), &EqualAllocator, &delay, &quality);
        });
        println!("joint_solve K=20 (equal)        {:>10.3} ms", t_eq * 1e3);
    }

    // ---- PJRT execution per bucket ----
    let dir = default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        let t_load = {
            let t0 = std::time::Instant::now();
            let s = ArtifactStore::load(&dir).unwrap();
            let dt = t0.elapsed().as_secs_f64();
            drop(s);
            dt
        };
        println!("artifact_load+compile (9 hlo)   {:>10.1} ms", t_load * 1e3);
        let store = ArtifactStore::load(&dir).unwrap();
        let mut exec = DenoiseExecutor::new(&store);
        let dim = exec.data_dim();
        let mut rng = Pcg64::seeded(5);
        for bucket in [1u32, 8, 32] {
            let latents: Vec<Vec<f32>> = (0..bucket as usize)
                .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
                .collect();
            let batch: Vec<BatchInput> = latents
                .iter()
                .map(|l| BatchInput { latent: l, t_cur: 500, t_prev: 450 })
                .collect();
            let _ = exec.step(&batch).unwrap(); // warmup
            let t = median_of(reps, || {
                let _ = exec.step(&batch).unwrap();
            });
            println!(
                "pjrt_step bucket={bucket:<3}            {:>10.3} ms ({:.3} ms/task)",
                t * 1e3,
                t * 1e3 / bucket as f64
            );
        }
    } else {
        println!("(artifacts missing — skipping PJRT micro-benches)");
    }
    println!("\nmicro_hotpath OK");
}
