//! Pipeline sweep — solve latency × lifecycle mode × fleet view on a
//! heterogeneous 4-server fleet under bursty arrivals, through the
//! zero-fault event engine.
//! (`harness = false`: criterion is not in the offline vendored set.)
//!
//! Acceptance properties asserted here (ISSUE 4):
//!  * the sweep covers ≥ 10⁴ simulated requests;
//!  * the whole run is deterministic — same seed, bit-identical rows;
//!  * at zero solve latency, pipelined and synchronous modes are
//!    bit-identical (the historical semantics);
//!  * at every nonzero solve latency, the pipelined mode strictly
//!    beats the synchronous mode on mean deadline-censored end-to-end
//!    delay (the solve hides behind GPU execution instead of idling
//!    it) and reports a nonzero solve-overlap fraction;
//!  * under the bursty arrivals, the live-state router is no worse
//!    than the stale virtual-queue JSQ view on the censored p99 tail.

use aigc_edge::bench;
use aigc_edge::config::ExperimentConfig;
use aigc_edge::coordinator::SolveMode;
use aigc_edge::routing::RouterKind;

fn main() {
    let mut cfg = ExperimentConfig::paper();
    cfg.cluster.servers = 4;
    cfg.cluster.speed_min = 0.5;
    cfg.cluster.speed_max = 2.0;
    // Bursty arrivals: 4 Hz base, 16 Hz peaks for a quarter of every
    // minute — mean ≈ 7 Hz, enough to backlog the fleet in bursts.
    cfg.arrival.rate_hz = 4.0;
    cfg.arrival.burst_rate_hz = 16.0;
    cfg.arrival.period_s = 60.0;
    cfg.arrival.duty = 0.25;
    let horizon_s: f64 = std::env::var("BENCH_HORIZON_S")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(500.0);

    // ---- solve-latency × mode × router sweep ----
    let solve_latencies = [0.0, 0.25, 0.5];
    let rows = bench::fig_pipeline(&cfg, &solve_latencies, horizon_s);

    // Each solve latency draws its own trace, shared by its four
    // cells; count unique arrivals once per latency.
    let total: usize = rows
        .iter()
        .filter(|r| r.mode == SolveMode::Pipelined && r.router == RouterKind::JoinShortestQueue)
        .map(|r| r.requests)
        .sum();
    assert!(total >= 10_000, "pipeline sweep must cover >= 10^4 simulated requests, got {total}");

    // Deterministic replay: identical seed -> bit-identical rows.
    let replay = bench::fig_pipeline(&cfg, &solve_latencies, horizon_s);
    assert_eq!(rows, replay, "pipelined simulation is not deterministic");

    for latency in solve_latencies {
        for router in [RouterKind::JoinShortestQueue, RouterKind::LiveState] {
            let cell = |mode: SolveMode| {
                rows.iter()
                    .find(|r| {
                        r.solve_latency_s == latency && r.mode == mode && r.router == router
                    })
                    .expect("cell present")
            };
            let pipelined = cell(SolveMode::Pipelined);
            let sync = cell(SolveMode::Synchronous);
            assert_eq!(sync.solve_overlap, 0.0, "synchronous solves are never hidden");
            if latency == 0.0 {
                // Zero latency is the bit-identity case: the lifecycle
                // refactor must not move a single batch.
                assert_eq!(pipelined.served, sync.served, "{router:?}");
                assert_eq!(
                    pipelined.mean_e2e_censored_s.to_bits(),
                    sync.mean_e2e_censored_s.to_bits(),
                    "{router:?}: zero-latency modes must be bit-identical"
                );
                assert_eq!(
                    pipelined.mean_quality.to_bits(),
                    sync.mean_quality.to_bits(),
                    "{router:?}"
                );
            } else {
                assert!(
                    pipelined.solve_overlap > 0.0,
                    "{router:?} @ {latency}s: bursty backlog must hide some solve time"
                );
                assert!(
                    pipelined.mean_e2e_censored_s < sync.mean_e2e_censored_s,
                    "{router:?} @ {latency}s: pipelined mean censored e2e {} must strictly \
                     beat synchronous {}",
                    pipelined.mean_e2e_censored_s,
                    sync.mean_e2e_censored_s
                );
            }
        }
    }

    // ---- stale virtual queue vs live view, default pipelined mode ----
    // Report the gap at every latency; assert dominance where the
    // routing signals diverge most (deepest backlog = largest solve
    // latency), so the guard pins the headline cell without gating on
    // quantile noise in the near-tie regimes.
    let max_latency = solve_latencies.iter().copied().fold(0.0, f64::max);
    for latency in solve_latencies {
        let cell = |router: RouterKind| {
            rows.iter()
                .find(|r| {
                    r.solve_latency_s == latency
                        && r.mode == SolveMode::Pipelined
                        && r.router == router
                })
                .expect("cell present")
        };
        let live = cell(RouterKind::LiveState);
        let stale = cell(RouterKind::JoinShortestQueue);
        println!(
            "live-vs-stale @ {latency}s solve latency: censored p99 {:.2}s vs {:.2}s, \
             mean {:.2}s vs {:.2}s",
            live.p99_e2e_censored_s,
            stale.p99_e2e_censored_s,
            live.mean_e2e_censored_s,
            stale.mean_e2e_censored_s
        );
        if latency == max_latency {
            assert!(
                live.p99_e2e_censored_s <= stale.p99_e2e_censored_s,
                "@ {latency}s: live router censored p99 {} must not exceed the stale \
                 virtual-queue view's {}",
                live.p99_e2e_censored_s,
                stale.p99_e2e_censored_s
            );
        }
    }

    let demo = rows
        .iter()
        .find(|r| {
            r.solve_latency_s > 0.0
                && r.mode == SolveMode::Pipelined
                && r.router == RouterKind::LiveState
        })
        .unwrap();
    println!(
        "\nfig_pipeline OK ({total} simulated requests; @ {}s solve latency the pipelined \
         live-view cell hides {:.0}% of solve time)",
        demo.solve_latency_s,
        100.0 * demo.solve_overlap
    );
}
