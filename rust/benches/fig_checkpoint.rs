//! Checkpointed-denoising showdown behind `BENCH_pr7.json`.
//! (`harness = false`: criterion is not in the offline vendored set.)
//!
//! Acceptance properties asserted here (ISSUE 7):
//!  * under scheduled mid-trace deaths on a heterogeneous fleet,
//!    checkpoint-on-death strictly beats requeue-on-death on served
//!    requests and on the deadline-censored post-failure p99, and
//!    requeue strictly beats no migration — in-flight work dies with
//!    its server under every policy, and only the checkpoint column
//!    salvages the finished step boundaries;
//!  * the checkpoint column actually resumes work (resumed > 0,
//!    recovered steps > 0);
//!  * the whole figure replays bit-identically;
//!  * with an empty fault script, `CheckpointOnDeath` is bit-identical
//!    to no migration at a nonzero transfer cost — the checkpoint
//!    machinery is pure overhead-free bookkeeping until a server dies.

use std::path::Path;

use aigc_edge::bandwidth::EqualAllocator;
use aigc_edge::bench;
use aigc_edge::config::ExperimentConfig;
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::faults::{FaultScript, MigrationPolicyKind};
use aigc_edge::quality::PowerLawQuality;
use aigc_edge::scheduler::Stacking;
use aigc_edge::sim::{server_speeds, simulate_event_cluster, EventClusterConfig};
use aigc_edge::trace::ArrivalTrace;

fn main() {
    let mut cfg = ExperimentConfig::paper();
    cfg.cluster.servers = 4;
    cfg.cluster.speed_min = 0.5;
    cfg.cluster.speed_max = 2.0;
    cfg.arrival.rate_hz = 6.0;
    let horizon_s: f64 = std::env::var("BENCH_HORIZON_S")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400.0);

    // ---- migration-policy showdown on one scheduled fault script ----
    let rows = bench::fig_checkpoint(&cfg, horizon_s);
    assert_eq!(rows.len(), MigrationPolicyKind::all().len());
    assert!(rows[0].requests > 1_000, "showdown too small: {} requests", rows[0].requests);
    let by = |p: MigrationPolicyKind| rows.iter().find(|r| r.policy == p).unwrap();
    let none = by(MigrationPolicyKind::None);
    let requeue = by(MigrationPolicyKind::RequeueOnDeath);
    let checkpoint = by(MigrationPolicyKind::Checkpoint);
    assert!(none.lost_to_failure > 0, "the scheduled deaths must strand work");
    assert!(
        requeue.served > none.served,
        "requeue-on-death must strictly beat no-migration on served: {} vs {}",
        requeue.served,
        none.served
    );
    assert!(
        checkpoint.served > requeue.served,
        "checkpoint-on-death must strictly beat requeue-on-death on served: {} vs {}",
        checkpoint.served,
        requeue.served
    );
    assert!(checkpoint.resumed > 0, "checkpoint salvaged no in-flight requests");
    assert!(checkpoint.recovered_steps > 0, "checkpoint salvaged no steps");
    for r in &rows {
        if r.policy != MigrationPolicyKind::Checkpoint {
            assert_eq!(r.resumed, 0, "{:?} resumed without checkpoints", r.policy);
            assert_eq!(r.recovered_steps, 0, "{:?} salvaged steps", r.policy);
        }
    }
    assert!(
        checkpoint.post_failure_p99_s < requeue.post_failure_p99_s,
        "checkpoint must strictly beat requeue on the censored post-failure p99: {} vs {}",
        checkpoint.post_failure_p99_s,
        requeue.post_failure_p99_s
    );

    // ---- deterministic replay: identical seed -> bit-identical rows ----
    let replay = bench::fig_checkpoint(&cfg, horizon_s);
    assert_eq!(rows, replay, "checkpoint showdown is not deterministic");

    // ---- zero-fault bitwise degeneration ----
    let scheduler = Stacking::default();
    let allocator = EqualAllocator;
    let delay = BatchDelayModel::new(cfg.delay.a, cfg.delay.b);
    let quality = PowerLawQuality::paper();
    let mut arrival = cfg.arrival;
    arrival.horizon_s = 60.0;
    let short = ArrivalTrace::generate(&cfg.scenario, &arrival, cfg.seed);
    let speeds = server_speeds(4, 0.5, 2.0);
    let empty = FaultScript::empty();
    let run = |migration: MigrationPolicyKind, transfer_s: f64| {
        let event_cfg = EventClusterConfig {
            speeds: &speeds,
            router: cfg.cluster.router,
            dynamic: (&cfg.dynamic).into(),
            faults: &empty,
            migration,
            resume_transfer_s: transfer_s,
        };
        simulate_event_cluster(&short, &scheduler, &allocator, &delay, &quality, &event_cfg)
    };
    let baseline = run(MigrationPolicyKind::None, 0.0);
    let ckpt = run(MigrationPolicyKind::Checkpoint, 0.8);
    assert_eq!(
        ckpt.assignment, baseline.assignment,
        "zero-fault checkpoint dispatch must match no-migration"
    );
    assert_eq!(ckpt.resumed_elsewhere(), 0);
    assert_eq!(ckpt.recovered_steps(), 0);
    for (a, b) in ckpt.outcomes.iter().zip(&baseline.outcomes) {
        assert_eq!(a.disposition, b.disposition, "request {}", a.id);
        assert_eq!(a.steps, b.steps, "request {}", a.id);
        assert_eq!(a.quality.to_bits(), b.quality.to_bits(), "request {}", a.id);
        assert_eq!(a.resolved_s.to_bits(), b.resolved_s.to_bits(), "request {}", a.id);
    }
    assert_eq!(ckpt.horizon_s.to_bits(), baseline.horizon_s.to_bits());

    // ---- tracked trajectory: BENCH_pr7.json at the repository root ----
    let mut policies = String::new();
    for (i, r) in rows.iter().enumerate() {
        if i > 0 {
            policies.push_str(",\n");
        }
        policies.push_str(&format!(
            "    \"{}\": {{\n      \"served\": {},\n      \"lost_to_failure\": {},\n      \
             \"migrated\": {},\n      \"resumed\": {},\n      \"recovered_steps\": {},\n      \
             \"mean_quality\": {:?},\n      \"p99_e2e_s\": {:?},\n      \
             \"post_failure_p99_s\": {:?}\n    }}",
            r.policy.name(),
            r.served,
            r.lost_to_failure,
            r.migrated,
            r.resumed,
            r.recovered_steps,
            r.mean_quality,
            r.p99_e2e_s,
            r.post_failure_p99_s,
        ));
    }
    let json = format!(
        "{{\n  \"pr\": 7,\n  \"horizon_s\": {horizon_s:?},\n  \"requests\": {},\n  \
         \"policies\": {{\n{policies}\n  }}\n}}\n",
        rows[0].requests,
    );
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_pr7.json");
    std::fs::write(&path, &json).unwrap_or_else(|e| panic!("writing {}: {e}", path.display()));
    aigc_edge::util::json::parse(&json)
        .unwrap_or_else(|e| panic!("BENCH_pr7.json does not parse: {e}"));
    println!(
        "\nfig_checkpoint OK (served {} -> {} -> {}; resumed {} / {} steps; post-failure p99 \
         {:.2}s -> {:.2}s -> {:.2}s; wrote {})",
        none.served,
        requeue.served,
        checkpoint.served,
        checkpoint.resumed,
        checkpoint.recovered_steps,
        none.post_failure_p99_s,
        requeue.post_failure_p99_s,
        checkpoint.post_failure_p99_s,
        path.display()
    );
}
