//! Generation-cache showdown behind `BENCH_pr9.json`.
//! (`harness = false`: criterion is not in the offline vendored set.)
//!
//! Acceptance properties asserted here (ISSUE 9):
//!  * at high Zipf skew with a roomy per-server cache, cache-aware
//!    routing strictly beats virtual-queue JSQ on served (mean FID)
//!    quality AND on the deadline-censored p99 — placement-aware
//!    dispatch turns content reuse into both quality and tail wins;
//!  * the cache actually fires: hits > 0 on the cache-aware column and
//!    hit rate grows with skew;
//!  * the whole sweep replays bit-identically;
//!  * a cache-disabled run of the same marked trace is bit-identical
//!    to the same trace with every prompt mark stripped — the feature
//!    is invisible until switched on.

use std::path::Path;

use aigc_edge::bandwidth::EqualAllocator;
use aigc_edge::bench;
use aigc_edge::config::ExperimentConfig;
use aigc_edge::delay::BatchDelayModel;
use aigc_edge::faults::{MigrationPolicyKind, NO_FAULTS};
use aigc_edge::quality::PowerLawQuality;
use aigc_edge::routing::RouterKind;
use aigc_edge::scheduler::Stacking;
use aigc_edge::sim::{server_speeds, simulate_event_cluster, EventClusterConfig};
use aigc_edge::trace::{ArrivalTrace, PromptMark};

fn main() {
    let mut cfg = ExperimentConfig::paper();
    cfg.cluster.servers = 4;
    cfg.cluster.speed_min = 0.5;
    cfg.cluster.speed_max = 2.0;
    cfg.arrival.rate_hz = 8.0;
    let horizon_s: f64 = std::env::var("BENCH_HORIZON_S")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400.0);

    // ---- Zipf skew × capacity × router sweep ----
    let zipf = [0.6, 1.2, 1.8];
    let capacities = [8usize, 64];
    let rows = bench::fig_cache(&cfg, &zipf, &capacities, horizon_s);
    assert_eq!(rows.len(), zipf.len() * capacities.len() * 2);
    assert!(rows[0].requests > 1_000, "sweep too small: {} requests", rows[0].requests);
    let by = |s: f64, cap: usize, router: RouterKind| {
        rows.iter()
            .find(|r| r.zipf_s == s && r.capacity == cap && r.router == router)
            .unwrap_or_else(|| panic!("missing cell ({s}, {cap}, {})", router.name()))
    };

    // The headline claim: at high skew with a roomy cache, the
    // cache-aware router strictly beats JSQ on the (P0) mean-quality
    // objective (lower FID is better) and on the censored p99.
    let hot_ca = by(1.8, 64, RouterKind::CacheAware);
    let hot_jsq = by(1.8, 64, RouterKind::JoinShortestQueue);
    assert!(hot_ca.served_from_cache > 0, "the hot cell never hit its caches: {hot_ca:?}");
    assert!(
        hot_ca.mean_quality < hot_jsq.mean_quality,
        "cache-aware must strictly beat JSQ on served quality at high skew: {} vs {}",
        hot_ca.mean_quality,
        hot_jsq.mean_quality
    );
    assert!(
        hot_ca.p99_e2e_censored_s < hot_jsq.p99_e2e_censored_s,
        "cache-aware must strictly beat JSQ on the censored p99 at high skew: {} vs {}",
        hot_ca.p99_e2e_censored_s,
        hot_jsq.p99_e2e_censored_s
    );
    // Skew helps reuse: the cache-aware hit rate is monotone-ish in s
    // (strict at the extremes, where the effect is unambiguous).
    let cold_ca = by(0.6, 64, RouterKind::CacheAware);
    assert!(
        hot_ca.hit_rate > cold_ca.hit_rate,
        "hit rate must grow with skew: s=1.8 {} vs s=0.6 {}",
        hot_ca.hit_rate,
        cold_ca.hit_rate
    );

    // ---- deterministic replay: identical seed -> bit-identical rows ----
    let replay = bench::fig_cache(&cfg, &zipf, &capacities, horizon_s);
    assert_eq!(rows, replay, "cache sweep is not deterministic");

    // ---- cache-disabled bitwise invisibility on a marked trace ----
    let scheduler = Stacking::default();
    let allocator = EqualAllocator;
    let delay = BatchDelayModel::new(cfg.delay.a, cfg.delay.b);
    let quality = PowerLawQuality::paper();
    let mut arrival = cfg.arrival;
    arrival.horizon_s = 60.0;
    arrival.prompt_universe = 64;
    arrival.zipf_s = 1.8;
    arrival.models = 2;
    let marked = ArrivalTrace::generate(&cfg.scenario, &arrival, cfg.seed);
    let mut stripped = marked.clone();
    for a in &mut stripped.arrivals {
        a.mark = PromptMark::ZERO;
    }
    let speeds = server_speeds(4, 0.5, 2.0);
    let run = |trace: &ArrivalTrace| {
        let event_cfg = EventClusterConfig {
            speeds: &speeds,
            router: cfg.cluster.router,
            dynamic: (&cfg.dynamic).into(),
            faults: &NO_FAULTS,
            migration: MigrationPolicyKind::None,
            resume_transfer_s: 0.0,
        };
        simulate_event_cluster(trace, &scheduler, &allocator, &delay, &quality, &event_cfg)
    };
    let a = run(&marked);
    let b = run(&stripped);
    assert_eq!(a.assignment, b.assignment, "marks leaked into cache-disabled dispatch");
    assert_eq!(a.horizon_s.to_bits(), b.horizon_s.to_bits());
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.disposition, y.disposition, "request {}", x.id);
        assert_eq!(x.quality.to_bits(), y.quality.to_bits(), "request {}", x.id);
        assert_eq!(x.resolved_s.to_bits(), y.resolved_s.to_bits(), "request {}", x.id);
    }

    // ---- tracked trajectory: BENCH_pr9.json at the repository root ----
    let mut cells = String::new();
    for (i, r) in rows.iter().enumerate() {
        if i > 0 {
            cells.push_str(",\n");
        }
        cells.push_str(&format!(
            "    \"s{}_cap{}_{}\": {{\n      \"served\": {},\n      \
             \"served_from_cache\": {},\n      \"hit_rate\": {:?},\n      \"swaps\": {},\n      \
             \"mean_quality\": {:?},\n      \"outage_rate\": {:?},\n      \
             \"p99_e2e_censored_s\": {:?}\n    }}",
            r.zipf_s,
            r.capacity,
            r.router.name(),
            r.served,
            r.served_from_cache,
            r.hit_rate,
            r.swaps,
            r.mean_quality,
            r.outage_rate,
            r.p99_e2e_censored_s,
        ));
    }
    let json = format!(
        "{{\n  \"pr\": 9,\n  \"horizon_s\": {horizon_s:?},\n  \"requests\": {},\n  \
         \"cells\": {{\n{cells}\n  }}\n}}\n",
        rows[0].requests,
    );
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_pr9.json");
    std::fs::write(&path, &json).unwrap_or_else(|e| panic!("writing {}: {e}", path.display()));
    aigc_edge::util::json::parse(&json)
        .unwrap_or_else(|e| panic!("BENCH_pr9.json does not parse: {e}"));
    println!(
        "\nfig_cache OK (hot cell: {} cached of {} served, hit rate {:.3}; FID {:.2} vs JSQ \
         {:.2}; censored p99 {:.2}s vs {:.2}s; wrote {})",
        hot_ca.served_from_cache,
        hot_ca.served,
        hot_ca.hit_rate,
        hot_ca.mean_quality,
        hot_jsq.mean_quality,
        hot_ca.p99_e2e_censored_s,
        hot_jsq.p99_e2e_censored_s,
        path.display()
    );
}
