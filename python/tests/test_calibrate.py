"""Calibration correctness: Fréchet distance + power-law fit (Fig. 1b path)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st

from compile.calibrate import fit_power_law, frechet_distance, sample_moments


class TestFrechetDistance:
    def test_identity_is_zero(self):
        mu = np.arange(8.0)
        cov = np.eye(8) * 2.0
        assert frechet_distance(mu, cov, mu, cov) == pytest.approx(0.0, abs=1e-6)

    def test_mean_shift_only(self):
        """With equal covariances, FD reduces to the mean distance."""
        cov = np.eye(4)
        a = np.zeros(4)
        b = np.array([3.0, 0.0, 0.0, 0.0])
        assert frechet_distance(a, cov, b, cov) == pytest.approx(3.0, rel=1e-6)

    def test_isotropic_covariances_closed_form(self):
        """FD² between N(0, s²I) and N(0, t²I) in dim d is d·(s−t)²."""
        d, s, t = 6, 2.0, 0.5
        fd = frechet_distance(np.zeros(d), s**2 * np.eye(d), np.zeros(d), t**2 * np.eye(d))
        assert fd == pytest.approx(np.sqrt(d) * (s - t), rel=1e-6)

    def test_symmetry(self):
        rng = np.random.default_rng(0)
        a_raw = rng.normal(size=(5, 5))
        b_raw = rng.normal(size=(5, 5))
        cov_a = a_raw @ a_raw.T + np.eye(5)
        cov_b = b_raw @ b_raw.T + np.eye(5)
        mu_a, mu_b = rng.normal(size=5), rng.normal(size=5)
        assert frechet_distance(mu_a, cov_a, mu_b, cov_b) == pytest.approx(
            frechet_distance(mu_b, cov_b, mu_a, cov_a), rel=1e-9
        )

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000), d=st.integers(2, 12))
    def test_nonnegative(self, seed, d):
        rng = np.random.default_rng(seed)
        a_raw = rng.normal(size=(d, d))
        b_raw = rng.normal(size=(d, d))
        fd = frechet_distance(
            rng.normal(size=d),
            a_raw @ a_raw.T + 0.1 * np.eye(d),
            rng.normal(size=d),
            b_raw @ b_raw.T + 0.1 * np.eye(d),
        )
        assert fd >= 0.0

    def test_sample_moments(self):
        rng = np.random.default_rng(1)
        xs = rng.normal(loc=3.0, scale=2.0, size=(50_000, 3))
        mu, cov = sample_moments(xs)
        np.testing.assert_allclose(mu, [3.0] * 3, atol=0.05)
        np.testing.assert_allclose(cov, 4.0 * np.eye(3), atol=0.15)


class TestPowerLawFit:
    def test_recovers_exact_power_law(self):
        ts = [1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48]
        c0, d0, e0 = 120.0, 0.8, 15.0
        qs = [c0 * t ** (-d0) + e0 for t in ts]
        c, d, e = fit_power_law(ts, qs)
        assert c == pytest.approx(c0, rel=0.05)
        assert d == pytest.approx(d0, rel=0.05)
        assert e == pytest.approx(e0, rel=0.05)

    def test_noisy_fit_monotone_prediction(self):
        rng = np.random.default_rng(2)
        ts = list(range(1, 50, 3))
        qs = [300.0 * t**-1.2 + 20.0 + rng.normal(0, 1.0) for t in ts]
        c, d, e = fit_power_law(ts, qs)
        pred = [c * t ** (-d) + e for t in ts]
        assert all(b <= a + 1e-9 for a, b in zip(pred, pred[1:]))
        assert d > 0

    def test_fit_on_flat_curve(self):
        """A constant curve must fit with c ≈ 0 (no spurious decay)."""
        ts = [1, 2, 4, 8, 16, 32]
        c, d, e = fit_power_law(ts, [50.0] * len(ts))
        assert abs(c) < 1e-6
        assert e == pytest.approx(50.0, rel=1e-6)
