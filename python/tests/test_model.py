"""L2 correctness: the ε-predictor, schedule, DDIM step, and sampling."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st

from compile import data
from compile.model import (
    DATA_DIM,
    NUM_TRAIN_STEPS,
    alpha_bar_schedule,
    ddim_sample,
    ddim_step,
    ddim_timesteps,
    eps_predictor,
    init_params,
    time_embedding,
)
from compile.train import eps_predictor_jnp


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def trained_params():
    """A briefly-trained model — enough for denoising to actually pull
    samples toward the data manifold (the untrained net cannot)."""
    from compile.train import train

    return train(iters=600, log_every=0)


class TestSchedule:
    def test_alpha_bar_monotone_decreasing(self):
        ab = np.asarray(alpha_bar_schedule())
        assert ab.shape == (NUM_TRAIN_STEPS + 1,)
        assert np.all(np.diff(ab) <= 1e-9)

    def test_alpha_bar_bounds(self):
        ab = np.asarray(alpha_bar_schedule())
        assert ab.max() <= 0.9999 + 1e-9
        assert ab.min() >= 1e-4 - 1e-12
        assert ab[0] == pytest.approx(0.9999)

    @settings(max_examples=20, deadline=None)
    @given(steps=st.integers(1, 200))
    def test_timesteps_strictly_decreasing_to_zero(self, steps):
        ts = np.asarray(ddim_timesteps(steps))
        assert ts.shape == (steps + 1,)
        assert ts[0] == NUM_TRAIN_STEPS
        assert ts[-1] == 0
        assert np.all(np.diff(ts) < 0)  # strict: every step does work

    def test_time_embedding_shape_and_range(self):
        emb = time_embedding(jnp.linspace(0, 1, 5))
        assert emb.shape == (5, 64)
        assert np.all(np.abs(np.asarray(emb)) <= 1.0 + 1e-6)


class TestEpsPredictor:
    def test_shapes(self, params):
        x = jnp.zeros((7, DATA_DIM))
        out = eps_predictor(params, x, jnp.full((7,), 0.5))
        assert out.shape == (7, DATA_DIM)

    def test_pallas_matches_jnp_forward(self, params):
        """The Pallas forward (used by the AOT artifacts) must equal the
        plain-jnp forward (used by training) — otherwise trained weights
        would not transfer to the exported HLO."""
        x = jax.random.normal(jax.random.PRNGKey(3), (20, DATA_DIM))
        t = jax.random.uniform(jax.random.PRNGKey(4), (20,))
        got = eps_predictor(params, x, t)
        want = eps_predictor_jnp(params, x, t)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)

    def test_time_dependence(self, params):
        """Predictor output must vary with the timestep input."""
        x = jax.random.normal(jax.random.PRNGKey(5), (4, DATA_DIM))
        a = eps_predictor(params, x, jnp.full((4,), 0.1))
        b = eps_predictor(params, x, jnp.full((4,), 0.9))
        assert float(jnp.max(jnp.abs(a - b))) > 1e-3


class TestDdimStep:
    def test_heterogeneous_rows_equal_singletons(self, params):
        """A mixed-timestep batch must produce exactly what each task would
        get alone — the property that makes batch denoising schedulable."""
        ab = alpha_bar_schedule()
        x = jax.random.normal(jax.random.PRNGKey(6), (6, DATA_DIM))
        t_cur = jnp.array([1000, 800, 600, 400, 200, 50], jnp.int32)
        t_prev = jnp.array([900, 600, 400, 200, 100, 0], jnp.int32)
        full = ddim_step(params, ab, x, t_cur, t_prev)
        for i in range(6):
            single = ddim_step(params, ab, x[i : i + 1], t_cur[i : i + 1], t_prev[i : i + 1])
            # tolerance: near t = T_train, 1/√ᾱ ≈ 100 amplifies the padded
            # kernel's f32 rounding; 1e-3 abs on O(10) latents is ~1e-4 rel.
            np.testing.assert_allclose(
                np.asarray(full[i : i + 1]), np.asarray(single), rtol=1e-3, atol=1e-3
            )

    @staticmethod
    def _chain_mean_norm(params, steps: int) -> float:
        ab = alpha_bar_schedule()
        x = jax.random.normal(jax.random.PRNGKey(7), (64, DATA_DIM))
        ts = ddim_timesteps(steps)
        for i in range(steps):
            t_cur = jnp.full((64,), ts[i], jnp.int32)
            t_prev = jnp.full((64,), ts[i + 1], jnp.int32)
            x = ddim_step(params, ab, x, t_cur, t_prev)
        assert bool(jnp.all(jnp.isfinite(x)))
        return float(jnp.mean(jnp.linalg.norm(x, axis=1)))

    def test_longer_chains_approach_data_manifold(self, trained_params):
        """Few-step DDIM on this model OVERSHOOTS (x̂₀ amplification at
        high noise levels inflates norms well above the data scale); the
        robust invariant — mirrored by the Rust integration test
        rust/tests/runtime_roundtrip.rs — is that the norm decreases
        monotonically toward the data scale as the step budget grows."""
        n4 = self._chain_mean_norm(trained_params, 4)
        n8 = self._chain_mean_norm(trained_params, 8)
        n16 = self._chain_mean_norm(trained_params, 16)
        assert n8 < n4, f"4-step {n4:.1f} vs 8-step {n8:.1f}"
        assert n16 < n8, f"8-step {n8:.1f} vs 16-step {n16:.1f}"

    def test_more_steps_better_quality(self, trained_params):
        """Fig. 1b's premise: quality improves (FD falls) with step budget."""
        from compile.calibrate import measure_quality

        fd2 = measure_quality(trained_params, 2, 512)
        fd16 = measure_quality(trained_params, 16, 512)
        assert fd16 < fd2


class TestSampling:
    def test_sample_shape(self, params):
        out = ddim_sample(params, jax.random.PRNGKey(0), 16, 4)
        assert out.shape == (16, DATA_DIM)
        assert np.all(np.isfinite(np.asarray(out)))

    def test_deterministic_given_key(self, params):
        a = ddim_sample(params, jax.random.PRNGKey(42), 8, 3)
        b = ddim_sample(params, jax.random.PRNGKey(42), 8, 3)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestData:
    def test_sample_shape_and_determinism(self):
        a = data.sample(jax.random.PRNGKey(1), 128)
        b = data.sample(jax.random.PRNGKey(1), 128)
        assert a.shape == (128, DATA_DIM)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_true_moments_match_empirical(self):
        mu, cov = data.true_moments()
        xs = np.asarray(data.sample(jax.random.PRNGKey(2), 20000))
        np.testing.assert_allclose(xs.mean(axis=0), np.asarray(mu), atol=0.05)
        emp_cov = np.cov(xs.T)
        np.testing.assert_allclose(emp_cov, np.asarray(cov), atol=0.12)

    def test_modes_well_separated(self):
        c = np.asarray(data.mode_centers())
        for i in range(len(c)):
            for j in range(i + 1, len(c)):
                assert np.linalg.norm(c[i] - c[j]) > 4 * data.MODE_STD
