"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; every comparison is assert_allclose
against :mod:`compile.kernels.ref` — the core correctness signal for the
AOT artifacts (whatever passes here is exactly what gets baked to HLO).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st

from compile.kernels import blocked_matmul, ddim_update, linear
from compile.kernels.matmul import mxu_utilization, vmem_bytes
from compile.kernels.ref import ddim_update_ref, linear_ref, matmul_ref

RTOL = 2e-5
ATOL = 2e-5


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------------------
# blocked_matmul
# ---------------------------------------------------------------------------
class TestBlockedMatmul:
    @pytest.mark.parametrize(
        "m,k,n",
        [
            (1, 64, 256),      # single-task batch (bucket 1)
            (8, 64, 256),      # sublane-aligned batch
            (32, 256, 64),     # top bucket, output projection
            (20, 256, 256),    # paper's K=20, hidden matmul
            (128, 128, 128),   # exactly one MXU tile
            (129, 128, 127),   # one-past-a-tile on both axes
            (17, 100, 33),     # nothing aligned
            (256, 512, 256),   # multi-tile on every axis
        ],
    )
    def test_matches_ref(self, m, k, n):
        x, w = rand(0, (m, k)), rand(1, (k, n))
        # abs tolerance grows with √K: the blocked kernel accumulates in a
        # different order than the oracle's single dot.
        atol = ATOL * max(1.0, np.sqrt(k))
        np.testing.assert_allclose(blocked_matmul(x, w), matmul_ref(x, w), rtol=RTOL, atol=atol)

    @settings(max_examples=40, deadline=None)
    @given(
        m=st.integers(1, 48),
        k=st.integers(1, 160),
        n=st.integers(1, 160),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shape_sweep(self, m, k, n, seed):
        x, w = rand(seed, (m, k)), rand(seed + 1, (k, n))
        np.testing.assert_allclose(blocked_matmul(x, w), matmul_ref(x, w), rtol=RTOL, atol=ATOL)

    @settings(max_examples=12, deadline=None)
    @given(
        bm=st.sampled_from([8, 16, 64, 128]),
        bn=st.sampled_from([128, 256]),
        bk=st.sampled_from([128, 256]),
    )
    def test_block_shape_invariance(self, bm, bn, bk):
        """The result must not depend on the chosen tiling."""
        x, w = rand(2, (33, 192)), rand(3, (192, 96))
        got = blocked_matmul(x, w, block_m=bm, block_n=bn, block_k=bk)
        np.testing.assert_allclose(got, matmul_ref(x, w), rtol=RTOL, atol=ATOL)

    def test_zero_sized_rejected(self):
        with pytest.raises(Exception):
            blocked_matmul(jnp.zeros((2, 3)), jnp.zeros((4, 5)))

    def test_non_2d_rejected(self):
        with pytest.raises(ValueError):
            blocked_matmul(jnp.zeros((2, 3, 4)), jnp.zeros((4, 5)))

    def test_bf16_supported(self):
        x = rand(4, (16, 128)).astype(jnp.bfloat16)
        w = rand(5, (128, 128)).astype(jnp.bfloat16)
        got = blocked_matmul(x, w).astype(jnp.float32)
        want = matmul_ref(x, w).astype(jnp.float32)
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)

    def test_linear_bias(self):
        x, w, b = rand(6, (12, 64)), rand(7, (64, 256)), rand(8, (256,))
        np.testing.assert_allclose(linear(x, w, b), linear_ref(x, w, b), rtol=RTOL, atol=ATOL)

    def test_vmem_estimate_under_budget(self):
        """Default tiling must fit comfortably in a 16 MiB VMEM budget."""
        assert vmem_bytes(128, 128, 128) < 16 * 2**20 / 8

    def test_mxu_utilization_sublane_padding(self):
        """Utilization is m / round_up(m, 8): saw-tooth with peaks at
        sublane multiples — the hardware shape behind the paper's marginal
        cost `a` being small for mid-size batches."""
        for m in range(1, 33):
            padded = ((m + 7) // 8) * 8
            assert mxu_utilization(m, 256, 64) == pytest.approx(m / padded)
        assert mxu_utilization(8, 256, 64) == pytest.approx(1.0)
        assert mxu_utilization(32, 256, 64) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# ddim_update
# ---------------------------------------------------------------------------
def make_ddim_args(seed, b, d):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, d))
    eps = jax.random.normal(ks[1], (b, d))
    ab_cur = jax.random.uniform(ks[2], (b,), minval=0.05, maxval=0.95)
    ab_prev = jnp.clip(ab_cur + jax.random.uniform(ks[3], (b,), minval=0.01, maxval=0.4), 0.0, 0.9999)
    return (
        x,
        eps,
        jnp.sqrt(ab_cur),
        jnp.sqrt(1.0 - ab_cur),
        jnp.sqrt(ab_prev),
        jnp.sqrt(1.0 - ab_prev),
    )


class TestDdimUpdate:
    @pytest.mark.parametrize("b", [1, 2, 5, 8, 20, 32])
    def test_matches_ref(self, b):
        args = make_ddim_args(b, b, 64)
        np.testing.assert_allclose(ddim_update(*args), ddim_update_ref(*args), rtol=1e-5, atol=1e-5)

    @settings(max_examples=40, deadline=None)
    @given(b=st.integers(1, 40), d=st.integers(1, 130), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_shape_sweep(self, b, d, seed):
        args = make_ddim_args(seed, b, d)
        np.testing.assert_allclose(ddim_update(*args), ddim_update_ref(*args), rtol=1e-5, atol=1e-5)

    def test_identity_step(self):
        """s' == s must be a no-op (x̂₀ recombined at the same noise level)."""
        x, eps, sa, s1m, _, _ = make_ddim_args(11, 7, 64)
        got = ddim_update(x, eps, sa, s1m, sa, s1m)
        np.testing.assert_allclose(got, x, rtol=1e-4, atol=1e-4)

    def test_full_denoise_recovers_x0(self):
        """Stepping to ᾱ' = 1 returns exactly the implied x̂₀."""
        x, eps, sa, s1m, _, _ = make_ddim_args(12, 6, 64)
        ones = jnp.ones_like(sa)
        zeros = jnp.zeros_like(sa)
        got = ddim_update(x, eps, sa, s1m, ones, zeros)
        x0 = (x - s1m[:, None] * eps) / sa[:, None]
        np.testing.assert_allclose(got, x0, rtol=1e-4, atol=1e-4)

    def test_rows_independent(self):
        """Row i's output must not depend on other rows (heterogeneous batch)."""
        args = make_ddim_args(13, 9, 64)
        full = ddim_update(*args)
        row3 = ddim_update(*(a[3:4] for a in args))
        np.testing.assert_allclose(full[3:4], row3, rtol=1e-5, atol=1e-5)

    def test_shape_validation(self):
        x = jnp.zeros((4, 8))
        v = jnp.ones((3,))
        with pytest.raises(ValueError):
            ddim_update(x, x, v, v, v, v)
