"""AOT lowering: the HLO-text artifacts must be loadable, parameterized
correctly, and numerically equal to the in-process model."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile.aot import lower_bucket, to_hlo_text, write_moments
from compile.model import DATA_DIM, alpha_bar_schedule, ddim_step, init_params
from compile import data


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def alpha_bar():
    return alpha_bar_schedule()


class TestLowering:
    def test_hlo_text_shape_signature(self, params, alpha_bar):
        text = lower_bucket(params, alpha_bar, batch=4)
        assert "HloModule" in text
        # three runtime parameters: x, t_cur, t_prev (weights are constants)
        assert f"f32[4,{DATA_DIM}]" in text
        assert "s32[4]" in text

    def test_weights_are_baked(self, params, alpha_bar):
        """No weight-shaped parameters may remain in the ENTRY computation
        (sub-computations — loop bodies — legitimately take tuple params)."""
        text = lower_bucket(params, alpha_bar, batch=2)
        entry_lines = []
        in_entry = False
        for line in text.splitlines():
            if line.startswith("ENTRY "):
                in_entry = True
            elif in_entry and line.strip() == "}":
                break
            elif in_entry:
                entry_lines.append(line)
        params_in_entry = [l for l in entry_lines if "= parameter(" in l or " parameter(" in l]
        assert len(params_in_entry) == 3, params_in_entry  # x, t_cur, t_prev only
        for line in params_in_entry:
            assert "f32[256" not in line, f"unbaked weight parameter: {line.strip()}"

    @pytest.mark.parametrize("batch", [1, 8, 32])
    def test_text_reparses(self, params, alpha_bar, batch):
        """The emitted HLO text must parse back into an HloModule — the
        same text-parse step the Rust runtime performs
        (`HloModuleProto::from_text_file`). Full numeric round-trip through
        PJRT is covered by the Rust integration tests
        (rust/tests/runtime_roundtrip.rs), which execute these artifacts
        and compare against expectations exported from this model."""
        text = lower_bucket(params, alpha_bar, batch)
        mod = xc._xla.hlo_module_from_text(text)
        assert mod is not None
        reparsed = mod.to_string()
        assert f"f32[{batch},{DATA_DIM}]" in reparsed

    def test_distinct_buckets_distinct_shapes(self, params, alpha_bar):
        t2 = lower_bucket(params, alpha_bar, 2)
        t8 = lower_bucket(params, alpha_bar, 8)
        assert f"f32[2,{DATA_DIM}]" in t2
        assert f"f32[8,{DATA_DIM}]" in t8


class TestMoments:
    def test_moments_bin_layout(self, tmp_path):
        path = write_moments(str(tmp_path))
        raw = np.fromfile(path, "<f4")
        assert raw.shape[0] == DATA_DIM + DATA_DIM * DATA_DIM
        mu, cov = data.true_moments()
        np.testing.assert_allclose(raw[:DATA_DIM], np.asarray(mu), rtol=1e-6)
        np.testing.assert_allclose(
            raw[DATA_DIM:].reshape(DATA_DIM, DATA_DIM), np.asarray(cov), rtol=1e-5, atol=1e-6
        )

    def test_cov_symmetric_psd(self):
        _, cov = data.true_moments()
        cov = np.asarray(cov, np.float64)
        np.testing.assert_allclose(cov, cov.T, atol=1e-6)
        assert np.linalg.eigvalsh(cov).min() > 0


class TestManifestContract:
    """The manifest written by `make artifacts` is the Rust runtime's
    source of truth; pin the fields it depends on."""

    MANIFEST = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")

    @pytest.mark.skipif(not os.path.exists(MANIFEST), reason="run `make artifacts` first")
    def test_manifest_fields(self):
        with open(self.MANIFEST) as f:
            m = json.load(f)
        assert m["data_dim"] == DATA_DIM
        assert m["buckets"] == sorted(m["buckets"])
        for b in m["buckets"]:
            entry = m["hlo"][str(b)]
            path = os.path.join(os.path.dirname(self.MANIFEST), entry["file"])
            assert os.path.exists(path), path
        assert m["io"]["tuple_output"] is True
