"""AOT compile path: train → calibrate → lower to HLO-text artifacts.

Runs ONCE at ``make artifacts``; the Rust coordinator is self-contained
afterwards. Per batch-size bucket X this emits
``artifacts/denoise_bX.hlo.txt`` — one DDIM step over a batch of X
heterogeneous denoising tasks, with the *trained weights and the ᾱ table
baked in as HLO constants* (so the Rust side feeds only latents and
per-row timestep indices).

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import calibrate as calibrate_mod
from . import data
from .model import (
    DATA_DIM,
    HIDDEN_DIM,
    NUM_TRAIN_STEPS,
    Params,
    alpha_bar_schedule,
    ddim_step,
)
from .train import DEFAULT_TRAIN_ITERS, train

# Batch-size buckets: the Rust runtime pads a scheduled batch X_n up to
# the nearest bucket. Dense near the small sizes where the marginal cost
# `a` matters most; the top bucket bounds K per batch.
DEFAULT_BUCKETS = [1, 2, 4, 8, 12, 16, 20, 24, 32]

SEED = 0


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (see module docstring).

    The default HLO printer ELIDES large constants as ``{...}`` — fatal
    here, since the trained weights are baked in as constants (the text
    parser would silently reload garbage; every output becomes NaN). Use
    explicit print options with ``print_large_constants=True``.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # jax's HLO printer emits source_end_line/... metadata attributes the
    # 0.5.1-era text parser rejects — drop metadata entirely.
    opts.print_metadata = False
    text = comp.get_hlo_module().to_string(opts)
    assert "{...}" not in text, "HLO printer elided a constant"
    return text


def load_or_train(out_dir: str, iters: int, seed: int = SEED) -> Params:
    """Train the ε-predictor, or reuse the cached weights if the training
    configuration is unchanged."""
    cache = os.path.join(out_dir, "weights.npz")
    tag = f"seed={seed} iters={iters} d={DATA_DIM} h={HIDDEN_DIM}"
    if os.path.exists(cache):
        blob = np.load(cache)
        if str(blob.get("tag")) == tag:
            print(f"[aot] reusing cached weights ({tag})")
            return Params(**{k: jnp.asarray(blob[k]) for k in Params._fields})
    params = train(seed=seed, iters=iters)
    np.savez(
        cache, tag=tag, **{k: np.asarray(getattr(params, k)) for k in Params._fields}
    )
    print(f"[aot] wrote {cache}")
    return params


def lower_bucket(params: Params, alpha_bar: jax.Array, batch: int) -> str:
    """Lower one DDIM step at batch size `batch`, weights baked as constants."""

    def step(x, t_cur, t_prev):
        return (ddim_step(params, alpha_bar, x, t_cur, t_prev),)

    spec_x = jax.ShapeDtypeStruct((batch, DATA_DIM), jnp.float32)
    spec_t = jax.ShapeDtypeStruct((batch,), jnp.int32)
    lowered = jax.jit(step).lower(spec_x, spec_t, spec_t)
    return to_hlo_text(lowered)


def write_golden(params: Params, alpha_bar: jax.Array, buckets: list[int], out_dir: str) -> dict:
    """Golden vectors for the Rust runtime's numeric round-trip tests.

    Per bucket B, layout (little-endian):
      f32 x[B*D] | i32 t_cur[B] | i32 t_prev[B] | f32 expected[B*D]
    where `expected` is the in-process model's output for those inputs.
    """
    golden = {}
    for b in buckets:
        key = jax.random.PRNGKey(10_000 + b)
        x = jax.random.normal(key, (b, DATA_DIM), jnp.float32)
        t_cur = jnp.linspace(NUM_TRAIN_STEPS, 50, b).round().astype(jnp.int32)
        t_prev = (t_cur - jnp.linspace(100, 50, b).round().astype(jnp.int32)).clip(0)
        expected = ddim_step(params, alpha_bar, x, t_cur, t_prev)
        name = f"golden_b{b}.bin"
        with open(os.path.join(out_dir, name), "wb") as f:
            f.write(np.asarray(x, "<f4").tobytes())
            f.write(np.asarray(t_cur, "<i4").tobytes())
            f.write(np.asarray(t_prev, "<i4").tobytes())
            f.write(np.asarray(expected, "<f4").tobytes())
        golden[str(b)] = name
        print(f"[aot] golden bucket {b:3d} -> {name}")
    return golden


def write_moments(out_dir: str) -> str:
    """Target-distribution moments for Rust-side Fréchet-distance checks:
    little-endian f32 [mu (d) | cov (d*d) row-major]."""
    mu, cov = data.true_moments()
    path = os.path.join(out_dir, "moments.bin")
    buf = np.concatenate([np.asarray(mu, np.float32).ravel(), np.asarray(cov, np.float32).ravel()])
    buf.astype("<f4").tofile(path)
    return path


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="artifacts directory")
    parser.add_argument("--buckets", default=",".join(map(str, DEFAULT_BUCKETS)))
    parser.add_argument("--train-iters", type=int, default=DEFAULT_TRAIN_ITERS)
    parser.add_argument(
        "--skip-calibration",
        action="store_true",
        help="skip the quality-vs-steps measurement (quick artifact rebuilds)",
    )
    parser.add_argument("--calib-samples", type=int, default=calibrate_mod.DEFAULT_NUM_SAMPLES)
    args = parser.parse_args()

    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    buckets = sorted({int(b) for b in args.buckets.split(",") if b})

    params = load_or_train(out_dir, args.train_iters)
    alpha_bar = alpha_bar_schedule()

    hlo_files = {}
    for b in buckets:
        text = lower_bucket(params, alpha_bar, b)
        name = f"denoise_b{b}.hlo.txt"
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        hlo_files[b] = {"file": name, "sha256_16": digest, "bytes": len(text)}
        print(f"[aot] bucket {b:3d} -> {name} ({len(text)} chars)")

    quality_path = os.path.join(out_dir, "quality.json")
    if args.skip_calibration and os.path.exists(quality_path):
        print("[aot] keeping existing quality.json")
    else:
        result = calibrate_mod.calibrate(params, num_samples=args.calib_samples)
        calibrate_mod.write_quality_json(result, quality_path)

    moments_path = write_moments(out_dir)
    print(f"[aot] wrote {moments_path}")
    golden = write_golden(params, alpha_bar, buckets, out_dir)

    manifest = {
        "data_dim": DATA_DIM,
        "hidden_dim": HIDDEN_DIM,
        "num_train_steps": NUM_TRAIN_STEPS,
        "seed": SEED,
        "train_iters": args.train_iters,
        "buckets": buckets,
        "hlo": {str(b): hlo_files[b] for b in buckets},
        "quality": "quality.json",
        "moments": "moments.bin",
        "golden": golden,
        "io": {
            "inputs": [
                {"name": "x", "shape": ["B", DATA_DIM], "dtype": "f32"},
                {"name": "t_cur", "shape": ["B"], "dtype": "i32"},
                {"name": "t_prev", "shape": ["B"], "dtype": "i32"},
            ],
            "outputs": [{"name": "x_next", "shape": ["B", DATA_DIM], "dtype": "f32"}],
            "tuple_output": True,
        },
    }
    manifest_path = os.path.join(out_dir, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote {manifest_path}")


if __name__ == "__main__":
    main()
