"""Synthetic target distribution for build-time diffusion training.

Stands in for CIFAR-10 (unavailable offline — DESIGN.md §5): a 4-mode
Gaussian mixture in d=64. Multi-modal so that few-step DDIM visibly
degrades quality (mode blur), giving the same sharp-then-flat
quality-vs-steps curve the paper measures (Fig. 1b), while the exact
first/second moments make the Fréchet-distance quality metric trivially
computable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .model import DATA_DIM

NUM_MODES = 4
MODE_SCALE = 2.0     # distance of mode centres from the origin
MODE_STD = 0.35      # within-mode standard deviation


def mode_centers() -> jax.Array:
    """Deterministic, well-separated mode centres, shape (NUM_MODES, DATA_DIM)."""
    key = jax.random.PRNGKey(1234)
    dirs = jax.random.normal(key, (NUM_MODES, DATA_DIM), jnp.float32)
    dirs = dirs / jnp.linalg.norm(dirs, axis=1, keepdims=True)
    return MODE_SCALE * dirs


def sample(key: jax.Array, n: int) -> jax.Array:
    """Draw ``n`` datapoints from the mixture, shape (n, DATA_DIM)."""
    k_mode, k_noise = jax.random.split(key)
    modes = jax.random.randint(k_mode, (n,), 0, NUM_MODES)
    centers = mode_centers()[modes]
    return centers + MODE_STD * jax.random.normal(k_noise, (n, DATA_DIM), jnp.float32)


def true_moments() -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact mean and covariance of the mixture (for Fréchet distance)."""
    c = mode_centers()
    mu = jnp.mean(c, axis=0)
    centered = c - mu
    cov = centered.T @ centered / NUM_MODES + MODE_STD**2 * jnp.eye(DATA_DIM)
    return mu, cov
