"""Layer-2 JAX model: the ε-predictor + DDIM step used for batch denoising.

The paper's generator is DDIM pretrained on CIFAR-10 (a UNet). Offline,
without CIFAR-10 or a pretrained checkpoint, we substitute the smallest
model that preserves the paper's *system* behaviour (DESIGN.md §5): an
MLP ε-predictor over a d=64 synthetic "image" distribution (4-mode
Gaussian mixture), trained at build time by :mod:`train`. All dense
compute goes through the Layer-1 Pallas kernels.

The exported computation is :func:`ddim_step`: **one denoising step over
a batch of heterogeneous tasks** — each row carries its own current /
previous timestep index, because a batch mixes tasks from different
services at different denoising depths. This is the unit the Rust
coordinator schedules (one `ddim_step` execution = one batch `n`, its
latency = g(X_n)).
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import ddim_update, linear

# ---------------------------------------------------------------------------
# Dimensions (kept deliberately small: training must finish in seconds on
# CPU at `make artifacts` time; the *system* behaviour, not model capacity,
# is what the reproduction exercises).
# ---------------------------------------------------------------------------
DATA_DIM = 64          # d: flattened synthetic "image"
HIDDEN_DIM = 256       # MLP width
TIME_EMB_DIM = 64      # sinusoidal time-embedding width
NUM_TRAIN_STEPS = 1000  # diffusion discretization T (DDIM subsamples it)


class Params(NamedTuple):
    """ε-predictor parameters (a pytree; NamedTuple keeps HLO arg order stable)."""

    w_in: jax.Array    # (DATA_DIM, HIDDEN_DIM)
    b_in: jax.Array    # (HIDDEN_DIM,)
    w_t: jax.Array     # (TIME_EMB_DIM, HIDDEN_DIM)
    b_t: jax.Array     # (HIDDEN_DIM,)
    w_mid: jax.Array   # (HIDDEN_DIM, HIDDEN_DIM)
    b_mid: jax.Array   # (HIDDEN_DIM,)
    w_out: jax.Array   # (HIDDEN_DIM, DATA_DIM)
    b_out: jax.Array   # (DATA_DIM,)


def init_params(key: jax.Array) -> Params:
    """He-initialised MLP parameters."""
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def he(k, fan_in, shape):
        return jax.random.normal(k, shape, jnp.float32) * math.sqrt(2.0 / fan_in)

    return Params(
        w_in=he(k1, DATA_DIM, (DATA_DIM, HIDDEN_DIM)),
        b_in=jnp.zeros((HIDDEN_DIM,), jnp.float32),
        w_t=he(k2, TIME_EMB_DIM, (TIME_EMB_DIM, HIDDEN_DIM)),
        b_t=jnp.zeros((HIDDEN_DIM,), jnp.float32),
        w_mid=he(k3, HIDDEN_DIM, (HIDDEN_DIM, HIDDEN_DIM)),
        b_mid=jnp.zeros((HIDDEN_DIM,), jnp.float32),
        w_out=he(k4, HIDDEN_DIM, (HIDDEN_DIM, DATA_DIM)),
        b_out=jnp.zeros((DATA_DIM,), jnp.float32),
    )


# ---------------------------------------------------------------------------
# Noise schedule — cosine ᾱ (Nichol & Dhariwal), clipped away from 0/1 so
# the DDIM x̂₀ division is always well-conditioned.
# ---------------------------------------------------------------------------
def alpha_bar_schedule(num_steps: int = NUM_TRAIN_STEPS) -> jax.Array:
    """ᾱ_t for t = 0..num_steps (index 0 is the clean-data end, ᾱ≈1)."""
    t = jnp.arange(num_steps + 1, dtype=jnp.float32) / num_steps
    f = jnp.cos((t + 0.008) / 1.008 * jnp.pi / 2.0) ** 2
    ab = f / f[0]
    return jnp.clip(ab, 1e-4, 0.9999)


def time_embedding(t_norm: jax.Array, dim: int = TIME_EMB_DIM) -> jax.Array:
    """Sinusoidal embedding of normalised timestep ``t ∈ [0, 1]``, shape (B, dim)."""
    half = dim // 2
    freqs = jnp.exp(jnp.linspace(0.0, math.log(1000.0), half))
    ang = t_norm[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def eps_predictor(
    params: Params, x: jax.Array, t_norm: jax.Array, *, interpret: bool = True
) -> jax.Array:
    """Predict the noise ε̂ in ``x`` at (per-row) normalised timestep ``t_norm``.

    Every matmul is the Layer-1 blocked Pallas kernel, so the whole step
    lowers into one HLO module with explicit tiling.
    """
    temb = time_embedding(t_norm)
    h = linear(x, params.w_in, params.b_in, interpret=interpret) + linear(
        temb, params.w_t, params.b_t, interpret=interpret
    )
    h = jax.nn.silu(h)
    h = jax.nn.silu(linear(h, params.w_mid, params.b_mid, interpret=interpret))
    return linear(h, params.w_out, params.b_out, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ddim_step(
    params: Params,
    alpha_bar: jax.Array,
    x: jax.Array,
    t_cur: jax.Array,
    t_prev: jax.Array,
    *,
    interpret: bool = True,
) -> jax.Array:
    """One DDIM denoising step over a heterogeneous batch.

    Args:
      params: trained ε-predictor weights.
      alpha_bar: ``(NUM_TRAIN_STEPS + 1,)`` schedule table.
      x: ``(B, DATA_DIM)`` latents; row i is a task from some service.
      t_cur: ``(B,)`` int32 current timestep index per row (1..T).
      t_prev: ``(B,)`` int32 target timestep index per row (< t_cur; 0 = clean).

    Returns:
      ``(B, DATA_DIM)`` latents advanced by one step.
    """
    ab_cur = alpha_bar[t_cur]
    ab_prev = alpha_bar[t_prev]
    t_norm = t_cur.astype(jnp.float32) / NUM_TRAIN_STEPS
    eps = eps_predictor(params, x, t_norm, interpret=interpret)
    return ddim_update(
        x,
        eps,
        jnp.sqrt(ab_cur),
        jnp.sqrt(1.0 - ab_cur),
        jnp.sqrt(ab_prev),
        jnp.sqrt(1.0 - ab_prev),
        interpret=interpret,
    )


def ddim_timesteps(num_steps: int, num_train: int = NUM_TRAIN_STEPS) -> jnp.ndarray:
    """The DDIM sub-sequence for a budget of ``num_steps`` denoising steps:
    a uniform grid ``num_train → 0`` with ``num_steps + 1`` knots."""
    return jnp.linspace(num_train, 0, num_steps + 1).round().astype(jnp.int32)


def ddim_sample(
    params: Params,
    key: jax.Array,
    num_samples: int,
    num_steps: int,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Generate ``num_samples`` datapoints with a ``num_steps``-step DDIM chain.

    Used by calibration (quality-vs-steps curve) and tests; the serving
    path instead advances one `ddim_step` per scheduled batch.
    """
    ab = alpha_bar_schedule()
    ts = ddim_timesteps(num_steps)
    x = jax.random.normal(key, (num_samples, DATA_DIM), jnp.float32)
    for i in range(num_steps):
        t_cur = jnp.full((num_samples,), ts[i], jnp.int32)
        t_prev = jnp.full((num_samples,), ts[i + 1], jnp.int32)
        x = ddim_step(params, ab, x, t_cur, t_prev, interpret=interpret)
    return x
