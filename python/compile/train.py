"""Build-time diffusion training for the ε-predictor.

Runs once inside ``make artifacts`` (seconds on CPU, deterministic
seed), producing the weights that :mod:`aot` bakes into the HLO
artifacts. Python never trains — or runs — on the serving path.

The training loop uses the plain-jnp forward pass (not the Pallas
kernels) for speed under jit; the pytest suite separately asserts the
Pallas forward is numerically identical, so the exported artifacts (which
DO use the kernels) match the trained weights.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import data
from .model import (
    NUM_TRAIN_STEPS,
    Params,
    alpha_bar_schedule,
    init_params,
    time_embedding,
)

LEARNING_RATE = 2e-3
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
BATCH_SIZE = 256
DEFAULT_TRAIN_ITERS = 4000


def eps_predictor_jnp(params: Params, x: jax.Array, t_norm: jax.Array) -> jax.Array:
    """Pure-jnp twin of :func:`model.eps_predictor` (same math, XLA-fused)."""
    temb = time_embedding(t_norm)
    h = x @ params.w_in + params.b_in + temb @ params.w_t + params.b_t
    h = jax.nn.silu(h)
    h = jax.nn.silu(h @ params.w_mid + params.b_mid)
    return h @ params.w_out + params.b_out


class AdamState(NamedTuple):
    step: jax.Array
    mu: Params
    nu: Params


def adam_init(params: Params) -> AdamState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=zeros)


def adam_update(params: Params, grads: Params, state: AdamState) -> tuple[Params, AdamState]:
    step = state.step + 1
    mu = jax.tree.map(lambda m, g: ADAM_B1 * m + (1 - ADAM_B1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: ADAM_B2 * v + (1 - ADAM_B2) * g * g, state.nu, grads)
    bc1 = 1 - ADAM_B1 ** step.astype(jnp.float32)
    bc2 = 1 - ADAM_B2 ** step.astype(jnp.float32)
    new_params = jax.tree.map(
        lambda p, m, v: p - LEARNING_RATE * (m / bc1) / (jnp.sqrt(v / bc2) + ADAM_EPS),
        params,
        mu,
        nu,
    )
    return new_params, AdamState(step=step, mu=mu, nu=nu)


def diffusion_loss(params: Params, alpha_bar: jax.Array, key: jax.Array) -> jax.Array:
    """Standard ε-prediction MSE at uniformly sampled timesteps."""
    k_data, k_t, k_noise = jax.random.split(key, 3)
    x0 = data.sample(k_data, BATCH_SIZE)
    t = jax.random.randint(k_t, (BATCH_SIZE,), 1, NUM_TRAIN_STEPS + 1)
    eps = jax.random.normal(k_noise, x0.shape, jnp.float32)
    ab = alpha_bar[t][:, None]
    x_t = jnp.sqrt(ab) * x0 + jnp.sqrt(1.0 - ab) * eps
    pred = eps_predictor_jnp(params, x_t, t.astype(jnp.float32) / NUM_TRAIN_STEPS)
    return jnp.mean((pred - eps) ** 2)


# NOTE: no buffer donation here — adam_init builds mu/nu with zeros_like,
# and XLA shares the zero constant across them, so donating the optimizer
# state would donate one buffer twice.
@jax.jit
def _train_step(params: Params, opt: AdamState, alpha_bar: jax.Array, key: jax.Array):
    loss, grads = jax.value_and_grad(diffusion_loss)(params, alpha_bar, key)
    params, opt = adam_update(params, grads, opt)
    return params, opt, loss


def train(seed: int = 0, iters: int = DEFAULT_TRAIN_ITERS, log_every: int = 500) -> Params:
    """Train the ε-predictor; deterministic for a fixed seed."""
    key = jax.random.PRNGKey(seed)
    params = init_params(key)
    opt = adam_init(params)
    ab = alpha_bar_schedule()
    for i in range(iters):
        key, sub = jax.random.split(key)
        params, opt, loss = _train_step(params, opt, ab, sub)
        if log_every and (i % log_every == 0 or i == iters - 1):
            print(f"[train] iter {i:5d} loss {float(loss):.4f}")
    return params
