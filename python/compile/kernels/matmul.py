"""Blocked Pallas matmul — the MXU-shaped linear-layer kernel.

The paper's GPU hot-spot is the UNet's dense compute inside each
denoising step. Here every linear layer of the ε-predictor goes through
this kernel. The TPU adaptation (DESIGN.md §Hardware-Adaptation): the
CUDA threadblock tiling becomes a ``BlockSpec`` HBM↔VMEM schedule, with
(block_m × block_k) and (block_k × block_n) panels resident in VMEM and
an MXU-systolic ``jnp.dot`` per block.

The batch dimension (number of denoising tasks packed into one batch,
``X_n`` in the paper) is the M axis, so per-step latency is affine in
the batch size — the empirical Eq. (4) ``g(X) = aX + b``.

``interpret=True`` everywhere: the CPU PJRT plugin executes the kernel
as plain HLO; real-TPU lowering would emit a Mosaic custom-call.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-friendly default tiles. f32 sublane×lane is (8, 128); the MXU
# systolic array is 128×128, so 128-multiples keep it saturated. For the
# small shapes used by the d=64 denoiser we shrink the block to the
# (padded) problem size instead of forcing a 128 pad.
DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_K = 128

_SUBLANE = 8
_LANE = 128


def _round_up(value: int, multiple: int) -> int:
    return ((value + multiple - 1) // multiple) * multiple


def _pick_block(dim: int, preferred: int, multiple: int) -> int:
    """Largest tile ≤ preferred that is a multiple of `multiple` and
    covers `dim` if the whole (padded) axis fits in one block."""
    padded = _round_up(max(dim, 1), multiple)
    return min(padded, preferred)


def _matmul_kernel(x_ref, w_ref, o_ref):
    """Grid = (m_blocks, n_blocks, k_steps); the output block is revisited
    across the K axis (its index_map ignores ``kk``), so it stays resident
    in VMEM and serves as the accumulator — the canonical Pallas matmul
    schedule."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def blocked_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    """Compute ``x @ w`` with an explicitly tiled Pallas kernel.

    Arbitrary (M, K) x (K, N) shapes are supported: inputs are padded up
    to tile multiples, the kernel runs on the padded problem, and the
    result is sliced back. Padding with zeros is exact for matmul.
    """
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"blocked_matmul expects 2-D operands, got {x.shape} @ {w.shape}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")

    bm = _pick_block(m, block_m, _SUBLANE)
    bn = _pick_block(n, block_n, _LANE)
    bk = _pick_block(k, block_k, _LANE)

    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k))) if (mp != m or kp != k) else x
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n))) if (kp != k or np_ != n) else w

    k_steps = kp // bk
    grid = (mp // bm, np_ // bn, k_steps)

    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]


def linear(x: jax.Array, w: jax.Array, b: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Dense layer ``x @ w + b`` on the Pallas matmul."""
    return blocked_matmul(x, w, interpret=interpret) + b


def vmem_bytes(block_m: int, block_n: int, block_k: int, dtype_bytes: int = 4) -> int:
    """Static VMEM footprint estimate for one grid step (DESIGN.md §Perf):
    an X panel, a W panel, the output block, and the f32 accumulator."""
    return dtype_bytes * (
        block_m * block_k + block_k * block_n + block_m * block_n
    ) + 4 * block_m * block_n


def mxu_utilization(m: int, n: int, k: int, block_m: int = DEFAULT_BLOCK_M) -> float:
    """Fraction of MXU rows doing useful work for a given batch size M —
    the quantity that decides the paper's marginal cost `a`."""
    eff_m = min(_round_up(max(m, 1), _SUBLANE), block_m)
    return m / eff_m
