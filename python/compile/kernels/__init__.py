"""Layer-1 Pallas kernels for the batch-denoising compute hot path.

Every kernel here runs under ``interpret=True`` (the CPU PJRT plugin
cannot execute Mosaic custom-calls), and has a pure-jnp oracle in
:mod:`ref` that pytest checks it against.
"""

from .matmul import blocked_matmul, linear
from .ddim_update import ddim_update

__all__ = ["blocked_matmul", "linear", "ddim_update"]
