"""Pure-jnp correctness oracles for every Pallas kernel.

These are the ground truth the pytest suite (and hypothesis sweeps)
compare the kernels against — the CORE L1 correctness signal.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Oracle for :func:`kernels.matmul.blocked_matmul`."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def linear_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Oracle for :func:`kernels.matmul.linear`."""
    return matmul_ref(x, w) + b


def ddim_update_ref(
    x: jax.Array,
    eps: jax.Array,
    sqrt_ab_cur: jax.Array,
    sqrt_1m_ab_cur: jax.Array,
    sqrt_ab_prev: jax.Array,
    sqrt_1m_ab_prev: jax.Array,
) -> jax.Array:
    """Oracle for :func:`kernels.ddim_update.ddim_update` (DDIM, η = 0)."""
    sa_cur = sqrt_ab_cur[:, None]
    s1m_cur = sqrt_1m_ab_cur[:, None]
    sa_prev = sqrt_ab_prev[:, None]
    s1m_prev = sqrt_1m_ab_prev[:, None]
    x0 = (x - s1m_cur * eps) / sa_cur
    return sa_prev * x0 + s1m_prev * eps
