"""Fused DDIM update — one VMEM pass per denoising task.

A DDIM (η=0) step from timestep ``s`` to ``s'`` is, per Song et al.:

    x̂₀   = (x_s − √(1−ᾱ_s)·ε̂) / √ᾱ_s
    x_s' = √ᾱ_s'·x̂₀ + √(1−ᾱ_s')·ε̂

Written naively in jnp this is seven elementwise HLO ops with HBM
round-trips between them; fused here it is a single kernel that reads
``x``, ``ε̂`` and four per-row scalars once.

Batch heterogeneity: each *row* of the batch is a denoising task from a
(possibly) different service sitting at its own timestep, so the ᾱ
coefficients arrive as per-row vectors — exactly what the paper's batch
denoising (tasks from different services in one batch) requires.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SUBLANE = 8
_LANE = 128


def _round_up(value: int, multiple: int) -> int:
    return ((value + multiple - 1) // multiple) * multiple


def _ddim_kernel(x_ref, eps_ref, sa_cur_ref, s1m_cur_ref, sa_prev_ref, s1m_prev_ref, o_ref):
    x = x_ref[...]
    eps = eps_ref[...]
    sa_cur = sa_cur_ref[...]      # √ᾱ_s        per row, shape (bm, 1)
    s1m_cur = s1m_cur_ref[...]    # √(1−ᾱ_s)
    sa_prev = sa_prev_ref[...]    # √ᾱ_s'
    s1m_prev = s1m_prev_ref[...]  # √(1−ᾱ_s')
    x0 = (x - s1m_cur * eps) / sa_cur
    o_ref[...] = sa_prev * x0 + s1m_prev * eps


@functools.partial(jax.jit, static_argnames=("interpret",))
def ddim_update(
    x: jax.Array,
    eps: jax.Array,
    sqrt_ab_cur: jax.Array,
    sqrt_1m_ab_cur: jax.Array,
    sqrt_ab_prev: jax.Array,
    sqrt_1m_ab_prev: jax.Array,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Fused DDIM update over a batch of heterogeneous denoising tasks.

    Args:
      x: ``(B, D)`` current latents, one row per denoising task.
      eps: ``(B, D)`` the ε-predictor output for each row.
      sqrt_ab_cur / sqrt_1m_ab_cur / sqrt_ab_prev / sqrt_1m_ab_prev:
        ``(B,)`` per-row schedule coefficients (each task has its own
        current / previous timestep).

    Returns:
      ``(B, D)`` latents advanced one denoising step.
    """
    if x.shape != eps.shape or x.ndim != 2:
        raise ValueError(f"x/eps shape mismatch: {x.shape} vs {eps.shape}")
    b, d = x.shape
    for name, v in (
        ("sqrt_ab_cur", sqrt_ab_cur),
        ("sqrt_1m_ab_cur", sqrt_1m_ab_cur),
        ("sqrt_ab_prev", sqrt_ab_prev),
        ("sqrt_1m_ab_prev", sqrt_1m_ab_prev),
    ):
        if v.shape != (b,):
            raise ValueError(f"{name} must be ({b},), got {v.shape}")

    bp = _round_up(b, _SUBLANE)
    dp = _round_up(d, _LANE)

    def pad_mat(m):
        return jnp.pad(m, ((0, bp - b), (0, dp - d))) if (bp != b or dp != d) else m

    def pad_col(v):
        # Pad rows with 1.0 so the padded lanes never divide by zero.
        col = v.reshape(b, 1)
        return jnp.pad(col, ((0, bp - b), (0, 0)), constant_values=1.0) if bp != b else col

    out = pl.pallas_call(
        _ddim_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((bp, dp), lambda i: (0, 0)),
            pl.BlockSpec((bp, dp), lambda i: (0, 0)),
            pl.BlockSpec((bp, 1), lambda i: (0, 0)),
            pl.BlockSpec((bp, 1), lambda i: (0, 0)),
            pl.BlockSpec((bp, 1), lambda i: (0, 0)),
            pl.BlockSpec((bp, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bp, dp), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, dp), x.dtype),
        interpret=interpret,
    )(
        pad_mat(x),
        pad_mat(eps),
        pad_col(sqrt_ab_cur),
        pad_col(sqrt_1m_ab_cur),
        pad_col(sqrt_ab_prev),
        pad_col(sqrt_1m_ab_prev),
    )
    return out[:b, :d]
