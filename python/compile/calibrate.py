"""Quality-vs-steps calibration — the reproduction of Fig. 1b.

Measures the generated-content quality of the trained model as a
function of the DDIM step budget T, then fits the paper's power law

    q(T) = c · T^(−d) + e                                   (Fig. 1b)

Quality metric: the **Fréchet distance** between the Gaussian moments of
generated samples and the exact moments of the target mixture —
identical to the FID formula with identity features (DESIGN.md §5):

    FD² = ‖μ₁ − μ₂‖² + tr(Σ₁ + Σ₂ − 2·(Σ₁Σ₂)^{1/2})

The measured curve and the fit are written to ``artifacts/quality.json``,
which the Rust side loads as its `TableQuality` / `PowerLaw` models.
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .model import Params, ddim_sample

DEFAULT_STEP_GRID = [1, 2, 3, 4, 5, 6, 8, 10, 12, 16, 20, 24, 32, 40, 50]
DEFAULT_NUM_SAMPLES = 2048


def frechet_distance(mu1, cov1, mu2, cov2) -> float:
    """FD between two Gaussians, via the eigendecomposition form of the
    matrix square root (covariances are symmetric PSD)."""
    mu1, cov1, mu2, cov2 = (np.asarray(a, np.float64) for a in (mu1, cov1, mu2, cov2))
    diff = mu1 - mu2
    # sqrtm(cov1 @ cov2) trace via symmetric factorization:
    # tr sqrt(C1 C2) = tr sqrt(S C2 S) with C1 = S S (S = C1^{1/2}, symmetric).
    vals1, vecs1 = np.linalg.eigh(cov1)
    s1 = (vecs1 * np.sqrt(np.clip(vals1, 0, None))) @ vecs1.T
    inner = s1 @ cov2 @ s1
    vals = np.linalg.eigvalsh(inner)
    tr_sqrt = np.sum(np.sqrt(np.clip(vals, 0, None)))
    fd2 = diff @ diff + np.trace(cov1) + np.trace(cov2) - 2.0 * tr_sqrt
    return float(np.sqrt(max(fd2, 0.0)))


def sample_moments(x) -> tuple[np.ndarray, np.ndarray]:
    x = np.asarray(x, np.float64)
    mu = x.mean(axis=0)
    xc = x - mu
    cov = xc.T @ xc / max(x.shape[0] - 1, 1)
    return mu, cov


def measure_quality(params: Params, steps: int, num_samples: int, seed: int = 7) -> float:
    """FD between DDIM(steps) samples and the exact target moments."""
    samples = ddim_sample(params, jax.random.PRNGKey(seed), num_samples, steps)
    mu_g, cov_g = sample_moments(samples)
    mu_t, cov_t = data.true_moments()
    return frechet_distance(mu_g, cov_g, np.asarray(mu_t), np.asarray(cov_t))


def fit_power_law(ts: list[int], qs: list[float]) -> tuple[float, float, float]:
    """Least-squares fit of q(T) = c·T^(−d) + e.

    d is grid-searched (the problem is linear in (c, e) for fixed d),
    matching how the paper fits Fig. 1b.
    """
    t = np.asarray(ts, np.float64)
    q = np.asarray(qs, np.float64)
    best = (np.inf, 1.0, 1.0, 0.0)
    for d in np.linspace(0.05, 4.0, 396):
        basis = t**(-d)
        a_mat = np.stack([basis, np.ones_like(basis)], axis=1)
        coef, *_ = np.linalg.lstsq(a_mat, q, rcond=None)
        resid = a_mat @ coef - q
        sse = float(resid @ resid)
        if sse < best[0]:
            best = (sse, float(coef[0]), float(d), float(coef[1]))
    _, c, d, e = best
    return c, d, e


def calibrate(
    params: Params,
    step_grid: list[int] | None = None,
    num_samples: int = DEFAULT_NUM_SAMPLES,
) -> dict:
    """Measure the full quality curve and fit the power law."""
    step_grid = step_grid or DEFAULT_STEP_GRID
    # T = 0 baseline: pure x_T noise, never denoised — the quality a
    # service that misses its deadline entirely delivers ("outage FID").
    noise = jax.random.normal(jax.random.PRNGKey(99), (num_samples, data.DATA_DIM))
    mu_n, cov_n = sample_moments(noise)
    mu_t, cov_t = data.true_moments()
    fd_noise = frechet_distance(mu_n, cov_n, np.asarray(mu_t), np.asarray(cov_t))
    print(f"[calibrate] T=  0  FD={fd_noise:8.4f} (outage baseline)")
    curve = []
    for t in step_grid:
        fd = measure_quality(params, t, num_samples)
        curve.append({"steps": t, "fd": fd})
        print(f"[calibrate] T={t:3d}  FD={fd:8.4f}")
    c, d, e = fit_power_law([p["steps"] for p in curve], [p["fd"] for p in curve])
    print(f"[calibrate] power-law fit: q(T) = {c:.4f} * T^(-{d:.4f}) + {e:.4f}")
    return {
        "metric": "frechet_distance_identity_features",
        "num_samples": num_samples,
        "fd_noise": fd_noise,
        "curve": curve,
        "power_law": {"c": c, "d": d, "e": e},
    }


def write_quality_json(result: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[calibrate] wrote {path}")
